//! Experiment harnesses: regenerate every paper table/figure
//! (`innerq exp <id>`). Latency tables (4, 5, 6, Fig. 4) live in
//! `rust/benches/`; this module owns the quality tables (1, 2, 7), the
//! bit-width table (3), the window ablation (Fig. 5), the M-sparsity study
//! (§6.2) and the GPU cost-model cross-check.

use crate::cache::ValSegment;
use crate::coordinator::Engine;
use crate::eval::{evaluate, harness::print_table, EvalConfig, EvalResult};
use crate::quant::{bitwidth, MethodConfig, Mode, QuantMethod};
use crate::runtime::Manifest;
use crate::simulator;
use crate::workload::corpus::CorpusGen;
use anyhow::Result;

fn methods_table1() -> Vec<QuantMethod> {
    vec![
        QuantMethod::BaselineFp16,
        QuantMethod::Kivi,
        QuantMethod::KiviSink,
        QuantMethod::TurboQuant,
        QuantMethod::InnerQBase,
        QuantMethod::InnerQHybrid,
        QuantMethod::InnerQSmall,
    ]
}

/// Run a method list over one EvalConfig, reusing the baseline logits.
fn run_suite(manifest: &Manifest, cfg: EvalConfig, methods: &[QuantMethod]) -> Result<Vec<EvalResult>> {
    let (base_res, base_logits) =
        evaluate(manifest, QuantMethod::BaselineFp16.config(), cfg, None)?;
    let mut rows = vec![base_res];
    for &m in methods.iter().filter(|&&m| m != QuantMethod::BaselineFp16) {
        let (r, _) = evaluate(manifest, m.config(), cfg, Some(&base_logits))?;
        rows.push(r);
        eprintln!("  [{}] done", m.name());
    }
    Ok(rows)
}

/// Table 1 substitute: short-context quality suite.
pub fn table1(manifest: &Manifest) -> Result<Vec<EvalResult>> {
    let cfg = EvalConfig { n_docs: 8, n_assign: 40, n_queries: 10, seed: 2026 };
    let rows = run_suite(manifest, cfg, &methods_table1())?;
    print_table("Table 1 (substitute): short-context recall suite (~210 tok)", &rows);
    Ok(rows)
}

/// Table 2 substitute: long-context quality suite.
pub fn table2(manifest: &Manifest) -> Result<Vec<EvalResult>> {
    let mut all = Vec::new();
    for (name, n_assign) in [("2k-token docs", 380usize), ("1k-token docs", 190)] {
        let cfg = EvalConfig { n_docs: 4, n_assign, n_queries: 8, seed: 1126 };
        let rows = run_suite(manifest, cfg, &methods_table1())?;
        print_table(&format!("Table 2 (substitute): {name}"), &rows);
        all.extend(rows);
    }
    Ok(all)
}

/// Table 3: effective bit-width accounting (exact reproduction).
pub fn table3() {
    println!("\n== Table 3: per-number effective bit-width (G=32, d_h=128) ==");
    println!(
        "{:<16} {:>6} {:>7} {:>6} {:>6} {:>9}",
        "method", "K int", "K ovh", "V int", "V ovh", "effective"
    );
    for row in bitwidth::table3() {
        println!(
            "{:<16} {:>6.0} {:>7.2} {:>6.0} {:>6.2} {:>9.2}",
            row.method.name(),
            row.key.integer,
            row.key.total() - row.key.integer,
            row.val.integer,
            row.val.total() - row.val.integer,
            row.effective()
        );
    }
    println!("(paper: kivi 3.0, turboquant 3.75, innerq_base 3.5, innerq_hybrid 3.25, innerq_small 3.0)");
}

/// Table 7: quantization-mode ablation on the recall suite.
pub fn table7(manifest: &Manifest) -> Result<()> {
    let cfg = EvalConfig { n_docs: 6, n_assign: 40, n_queries: 10, seed: 707 };
    let (base_res, base_logits) =
        evaluate(manifest, QuantMethod::BaselineFp16.config(), cfg, None)?;
    for val_bits in [3u8, 2] {
        let mut rows = vec![base_res.clone()];
        for (label, key_mode, val_mode) in [
            ("K:Sym,V:Sym", Mode::Sym, Mode::Sym),
            ("K:Sym,V:Asym", Mode::Sym, Mode::Asym),
            ("K:Asym,V:Sym", Mode::Asym, Mode::Sym),
            ("K:Asym,V:Asym", Mode::Asym, Mode::Asym),
            ("K:Sym,V:Hybrid", Mode::Sym, Mode::Hybrid),
        ] {
            let mut mc = QuantMethod::InnerQBase.config();
            mc.key_mode = key_mode;
            mc.val_mode = val_mode;
            mc.val_bits = val_bits;
            let (mut r, _) = evaluate(manifest, mc, cfg, Some(&base_logits))?;
            r.method = format!("{label}");
            rows.push(r);
            eprintln!("  [K:3,V:{val_bits} {label}] done");
        }
        print_table(
            &format!("Table 7 (substitute): quantization modes, K:3,V:{val_bits} (inner groups)"),
            &rows,
        );
    }
    Ok(())
}

/// Fig. 5: high-precision window split ablation (w_sink + w_recent = 128).
pub fn fig5(manifest: &Manifest) -> Result<()> {
    let cfg = EvalConfig { n_docs: 6, n_assign: 40, n_queries: 10, seed: 55 };
    let (_, base_logits) = evaluate(manifest, QuantMethod::BaselineFp16.config(), cfg, None)?;
    println!("\n== Fig. 5 (substitute): w_sink sweep, w_recent = 128 - w_sink ==");
    println!("{:<16} {:>7} {:>8} {:>8} {:>10}", "method", "w_sink", "NLL", "acc%", "agree%");
    for m in [
        QuantMethod::Kivi,
        QuantMethod::InnerQBase,
        QuantMethod::InnerQHybrid,
        QuantMethod::InnerQSmall,
    ] {
        for w_sink in [0usize, 16, 32, 64, 96, 128] {
            let mut mc = m.config();
            mc.w_sink = w_sink;
            mc.w_recent = 128 - w_sink;
            let (r, _) = evaluate(manifest, mc, cfg, Some(&base_logits))?;
            println!(
                "{:<16} {:>7} {:>8.4} {:>8.1} {:>10.1}",
                m.name(),
                w_sink,
                r.nll,
                r.accuracy * 100.0,
                r.agreement * 100.0
            );
        }
    }
    Ok(())
}

/// §6.2: measured sparsity of the hybrid mask M on real cache traffic.
pub fn msparsity(manifest: &Manifest) -> Result<()> {
    let engine = Engine::new(manifest.clone(), QuantMethod::InnerQHybrid.config())?;
    let mut gen = CorpusGen::new(99);
    let mut asym = 0usize;
    let mut total = 0usize;
    for _ in 0..6 {
        let doc = gen.document(120, 4);
        let mut tokens = vec![manifest.bos];
        tokens.extend(manifest.encode(&doc.text)?);
        let mut seq = engine.prefill(&tokens[..tokens.len() - 1])?;
        engine.decode_step(&mut [&mut seq], &[*tokens.last().unwrap()])?;
        for layer in &seq.caches {
            for hc in layer.heads() {
                if let ValSegment::Inner(s) = &hc.qv {
                    for p in &s.params {
                        total += 1;
                        asym += p.is_asym() as usize;
                    }
                }
            }
        }
    }
    let sparsity = 1.0 - asym as f64 / total.max(1) as f64;
    println!("\n== §6.2: hybrid mask M on real cache traffic ==");
    println!("groups: {total}, asymmetric: {asym}, sparsity (fraction symmetric): {sparsity:.3}");
    println!("(paper: ~0.99 average; distribution-dependent — see EXPERIMENTS.md)");
    Ok(())
}

/// GPU cost-model cross-check of Tables 4 / Fig. 4.
pub fn simulate() {
    let m = simulator::GpuModel::default();
    let lengths = [512usize, 1024, 2048, 4096, 8192, 16384, 32768];
    println!("\n== GPU cost model: predicted fused-kernel totals (µs), Llama-3.1-8B layer ==");
    print!("{:<16}", "method");
    for n in lengths {
        print!("{n:>8}");
    }
    println!();
    for method in QuantMethod::ALL {
        if method == QuantMethod::KiviSink {
            continue; // same kernels as KIVI
        }
        print!("{:<16}", method.name());
        for n in lengths {
            let (_, _, total) = simulator::table4_row(&m, method, n);
            print!("{total:>8.0}");
        }
        println!();
    }
    println!("\nspeedup of innerq_base @32768:");
    let (_, _, base) = simulator::table4_row(&m, QuantMethod::InnerQBase, 32768);
    for other in [QuantMethod::BaselineFp16, QuantMethod::Kivi, QuantMethod::TurboQuant] {
        let (_, _, t) = simulator::table4_row(&m, other, 32768);
        println!("  vs {:<14} {:.2}x", other.name(), t / base);
    }
}

/// Parse a `MethodConfig` override of the form used by the CLI, e.g.
/// `--method innerq_base`.
pub fn method_config(name: &str) -> Option<MethodConfig> {
    QuantMethod::parse(name).map(|m| m.config())
}

/// Quick textual description of a config (logging).
pub fn describe(cfg: &MethodConfig) -> String {
    format!(
        "{} K:{}b/{:?}/{:?} V:{}b/{:?}/{:?} sink={} recent={} norm={}",
        cfg.method.name(),
        cfg.key_bits,
        cfg.key_mode,
        cfg.key_grouping,
        cfg.val_bits,
        cfg.val_mode,
        cfg.val_grouping,
        cfg.w_sink,
        cfg.w_recent,
        cfg.key_norm
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_prints_and_matches() {
        table3(); // smoke (assertions live in quant::bitwidth)
    }

    #[test]
    fn method_config_parsing() {
        assert!(method_config("innerq_base").is_some());
        assert!(method_config("bogus").is_none());
        let c = method_config("kivi").unwrap();
        assert_eq!(c.key_grouping, crate::quant::Grouping::Outer);
        assert!(describe(&c).contains("kivi"));
    }
}
