//! Build-time ISA gate for the SIMD kernel arms.
//!
//! The AVX-512 intrinsics used by `kernels/simd_x86.rs` stabilized in Rust
//! 1.89; older toolchains must still build the crate (scalar + AVX2 + NEON
//! arms only). Cargo cannot express "cfg if rustc >= X", so this script
//! probes the compiler version and emits the `innerq_avx512` cfg when the
//! AVX-512 arm can compile. Runtime availability is a separate question —
//! `kernels::dispatch` still feature-detects `avx512f` before selecting the
//! arm.

use std::process::Command;

fn rustc_minor() -> Option<u32> {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (abc 2025-08-01)" -> minor = 89
    let ver = text.split_whitespace().nth(1)?;
    let mut parts = ver.split('.');
    let major: u32 = parts.next()?.parse().ok()?;
    let minor: u32 = parts.next()?.parse().ok()?;
    if major > 1 {
        return Some(u32::MAX);
    }
    Some(minor)
}

fn main() {
    // Declare the cfg so --check-cfg builds accept it (ignored by old cargo).
    println!("cargo:rustc-check-cfg=cfg(innerq_avx512)");
    let avx512_ok = rustc_minor().map_or(false, |minor| minor >= 89);
    if avx512_ok {
        println!("cargo:rustc-cfg=innerq_avx512");
    }
    println!("cargo:rerun-if-changed=build.rs");
}
