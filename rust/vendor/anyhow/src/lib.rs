//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The registry is not reachable from the build environment, so this vendor
//! crate provides the (small) API subset the workspace actually uses:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] macros, and the
//! [`Context`] extension trait for `Result` and `Option`. Errors are flat
//! strings — no backtraces, no downcasting — which is all the serving stack
//! needs for its diagnostics.

use std::fmt;

/// A string-backed error value. Like `anyhow::Error`, it deliberately does
/// NOT implement `std::error::Error`, so the blanket `From` below cannot
/// overlap with `impl From<T> for T`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// Any standard error converts with `?` (its Display text is captured).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a failure, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error { msg: c.to_string() })
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::Error::msg(format!($($arg)*))) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        assert_eq!(Some(3u32).context("x").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "zzz".parse()?;
            Ok(n)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("bad {} at {}", "thing", 3);
        assert_eq!(format!("{e}"), "bad thing at 3");
        assert_eq!(format!("{e:?}"), "bad thing at 3");
    }
}
