//! Offline stand-in for the `xla` (PJRT) crate.
//!
//! The real crate binds the PJRT C API and compiles HLO for the host CPU;
//! it is not available in the offline build environment. This vendor crate
//! keeps the same API surface (`HloModuleProto::from_text_file` →
//! `XlaComputation` → `PjRtClient::compile` → `PjRtLoadedExecutable::execute`)
//! backed by a small HLO-*text* interpreter instead.
//!
//! Supported opcodes: `parameter`, `constant` (scalar and 1-D list),
//! `broadcast` (with `dimensions={...}`), `convert`, the elementwise binary
//! ops `add / subtract / multiply / divide / maximum / minimum`, and
//! `tuple`. That covers the runtime smoke tests and the synthetic fake-model
//! artifacts used by the scheduler/server integration tests; a module using
//! anything else fails at `compile` with a clear error, exactly where the
//! real backend would surface an unsupported-program problem.

use std::collections::HashMap;

/// Stub error: a message, surfaced by the caller with `{:?}`.
pub struct Error(pub String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

fn err<T>(msg: impl Into<String>) -> Result<T, Error> {
    Err(Error(msg.into()))
}

// ---------------------------------------------------------------------------
// Literals
// ---------------------------------------------------------------------------

/// A host tensor (or tuple of tensors).
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

/// Element types accepted by [`Literal::vec1`] / [`Literal::to_vec`].
pub trait NativeType: Copy {
    fn vec1(v: &[Self]) -> Literal;
    fn from_literal(l: &Literal) -> Result<Vec<Self>, Error>;
}

impl NativeType for f32 {
    fn vec1(v: &[Self]) -> Literal {
        Literal::F32 { data: v.to_vec(), dims: vec![v.len() as i64] }
    }
    fn from_literal(l: &Literal) -> Result<Vec<Self>, Error> {
        match l {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => err(format!("literal is not f32: {other:?}")),
        }
    }
}

impl NativeType for i32 {
    fn vec1(v: &[Self]) -> Literal {
        Literal::I32 { data: v.to_vec(), dims: vec![v.len() as i64] }
    }
    fn from_literal(l: &Literal) -> Result<Vec<Self>, Error> {
        match l {
            Literal::I32 { data, .. } => Ok(data.clone()),
            other => err(format!("literal is not i32: {other:?}")),
        }
    }
}

impl Literal {
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::vec1(v)
    }

    fn len(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
            Literal::Tuple(parts) => parts.len(),
        }
    }

    /// Reinterpret the flat data with new dimensions (element count checked).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if self.len() as i64 != n {
            return err(format!("reshape: {} elements into dims {dims:?}", self.len()));
        }
        match self {
            Literal::F32 { data, .. } => {
                Ok(Literal::F32 { data: data.clone(), dims: dims.to_vec() })
            }
            Literal::I32 { data, .. } => {
                Ok(Literal::I32 { data: data.clone(), dims: dims.to_vec() })
            }
            Literal::Tuple(_) => err("cannot reshape a tuple literal"),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::from_literal(self)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        match self {
            Literal::Tuple(parts) => Ok(parts.clone()),
            other => err(format!("literal is not a tuple: {} elements", other.len())),
        }
    }
}

// ---------------------------------------------------------------------------
// HLO text parsing
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone)]
struct Shape {
    dtype: DType,
    dims: Vec<i64>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EwOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
}

#[derive(Debug, Clone)]
enum Op {
    Parameter(usize),
    Constant(Vec<f64>),
    Broadcast { operand: String, dimensions: Vec<usize> },
    Convert { operand: String },
    Elementwise { op: EwOp, lhs: String, rhs: String },
    Tuple(Vec<String>),
}

#[derive(Debug, Clone)]
struct Instr {
    name: String,
    shape: Option<Shape>, // None for tuple-shaped instructions
    op: Op,
    root: bool,
}

/// Parse `f32[4,24]{1,0}` (layout suffix optional) into a [`Shape`].
fn parse_shape(s: &str) -> Result<Shape, Error> {
    let s = s.trim();
    let open = match s.find('[') {
        Some(i) => i,
        None => return err(format!("shape without dims: '{s}'")),
    };
    let dtype = match &s[..open] {
        "f32" => DType::F32,
        "s32" | "u32" | "i32" => DType::I32,
        other => return err(format!("unsupported element type '{other}'")),
    };
    let close = match s.find(']') {
        Some(i) => i,
        None => return err(format!("unterminated shape dims: '{s}'")),
    };
    let body = &s[open + 1..close];
    let mut dims = Vec::new();
    for part in body.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.parse::<i64>() {
            Ok(d) => dims.push(d),
            Err(_) => return err(format!("bad dim '{part}' in shape '{s}'")),
        }
    }
    Ok(Shape { dtype, dims })
}

/// Find the index of the `)` matching the `(` at `open` (no strings in HLO
/// operand lists, so plain depth counting suffices).
fn matching_paren(s: &str, open: usize) -> Result<usize, Error> {
    let bytes = s.as_bytes();
    let mut depth = 0usize;
    for (i, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Ok(i);
                }
            }
            _ => {}
        }
    }
    err("unbalanced parentheses")
}

fn parse_instr(line: &str) -> Result<Instr, Error> {
    let mut line = line.trim();
    let root = line.starts_with("ROOT ");
    if root {
        line = line[5..].trim_start();
    }
    let eq = match line.find('=') {
        Some(i) => i,
        None => return err(format!("instruction without '=': '{line}'")),
    };
    let name = line[..eq].trim().trim_start_matches('%').to_string();
    let rest = line[eq + 1..].trim_start();

    // Shape: either a tuple `(...)` or a single `f32[...]{...}` token.
    let (shape, rest) = if rest.starts_with('(') {
        let close = matching_paren(rest, 0)?;
        (None, rest[close + 1..].trim_start())
    } else {
        let end = rest.find(char::is_whitespace).unwrap_or(rest.len());
        (Some(parse_shape(&rest[..end])?), rest[end..].trim_start())
    };

    // Opcode and operand list.
    let open = match rest.find('(') {
        Some(i) => i,
        None => return err(format!("instruction without operands: '{line}'")),
    };
    let opcode = rest[..open].trim();
    let close = matching_paren(rest, open)?;
    let args = &rest[open + 1..close];
    let attrs = &rest[close + 1..];
    let operand_names = || -> Vec<String> {
        args.split(',')
            .map(|a| a.trim().trim_start_matches('%').to_string())
            .filter(|a| !a.is_empty())
            .collect()
    };

    let op = match opcode {
        "parameter" => {
            let idx = args
                .trim()
                .parse::<usize>()
                .map_err(|_| Error(format!("bad parameter index '{args}'")))?;
            Op::Parameter(idx)
        }
        "constant" => {
            let body = args.trim();
            let vals = if let Some(stripped) = body.strip_prefix('{') {
                let inner = stripped.trim_end_matches('}');
                inner
                    .split(',')
                    .map(|v| v.trim().parse::<f64>())
                    .collect::<Result<Vec<f64>, _>>()
                    .map_err(|_| Error(format!("bad constant list '{body}'")))?
            } else {
                vec![body
                    .parse::<f64>()
                    .map_err(|_| Error(format!("bad constant '{body}'")))?]
            };
            Op::Constant(vals)
        }
        "broadcast" => {
            let names = operand_names();
            if names.len() != 1 {
                return err(format!("broadcast takes one operand, got '{args}'"));
            }
            let dimensions = match attrs.find("dimensions={") {
                Some(i) => {
                    let tail = &attrs[i + "dimensions={".len()..];
                    let end = tail
                        .find('}')
                        .ok_or_else(|| Error("unterminated dimensions attr".into()))?;
                    tail[..end]
                        .split(',')
                        .map(|v| v.trim())
                        .filter(|v| !v.is_empty())
                        .map(|v| v.parse::<usize>())
                        .collect::<Result<Vec<usize>, _>>()
                        .map_err(|_| Error("bad dimensions attr".into()))?
                }
                None => Vec::new(),
            };
            Op::Broadcast { operand: names.into_iter().next().unwrap(), dimensions }
        }
        "convert" => {
            let names = operand_names();
            if names.len() != 1 {
                return err(format!("convert takes one operand, got '{args}'"));
            }
            Op::Convert { operand: names.into_iter().next().unwrap() }
        }
        "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" => {
            let names = operand_names();
            if names.len() != 2 {
                return err(format!("{opcode} takes two operands, got '{args}'"));
            }
            let op = match opcode {
                "add" => EwOp::Add,
                "subtract" => EwOp::Sub,
                "multiply" => EwOp::Mul,
                "divide" => EwOp::Div,
                "maximum" => EwOp::Max,
                _ => EwOp::Min,
            };
            let mut it = names.into_iter();
            Op::Elementwise { op, lhs: it.next().unwrap(), rhs: it.next().unwrap() }
        }
        "tuple" => Op::Tuple(operand_names()),
        other => return err(format!("unsupported HLO opcode '{other}'")),
    };
    Ok(Instr { name, shape, op, root })
}

/// Parse the ENTRY computation of an HLO-text module.
fn parse_module(text: &str) -> Result<Vec<Instr>, Error> {
    let mut instrs = Vec::new();
    let mut in_entry = false;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with("//") {
            continue;
        }
        if !in_entry {
            if line.starts_with("ENTRY") {
                in_entry = true;
            }
            continue;
        }
        if line == "}" {
            break;
        }
        instrs.push(parse_instr(line)?);
    }
    if instrs.is_empty() {
        return err("no ENTRY computation found in HLO text");
    }
    if !instrs.iter().any(|i| i.root) {
        return err("ENTRY computation has no ROOT instruction");
    }
    Ok(instrs)
}

// ---------------------------------------------------------------------------
// Interpretation
// ---------------------------------------------------------------------------

fn materialize_constant(shape: &Option<Shape>, vals: &[f64]) -> Result<Literal, Error> {
    let shape = match shape {
        Some(s) => s,
        None => return err("tuple-shaped constant not supported"),
    };
    let n: i64 = shape.dims.iter().product();
    if vals.len() as i64 != n && !(vals.len() == 1 && n == 1) {
        return err(format!("constant has {} values for shape {:?}", vals.len(), shape.dims));
    }
    Ok(match shape.dtype {
        DType::F32 => Literal::F32 {
            data: vals.iter().map(|&v| v as f32).collect(),
            dims: shape.dims.clone(),
        },
        DType::I32 => Literal::I32 {
            data: vals.iter().map(|&v| v as i32).collect(),
            dims: shape.dims.clone(),
        },
    })
}

fn literal_dims(l: &Literal) -> Result<&[i64], Error> {
    match l {
        Literal::F32 { dims, .. } => Ok(dims),
        Literal::I32 { dims, .. } => Ok(dims),
        Literal::Tuple(_) => err("tuple has no array dims"),
    }
}

/// `out[idx] = operand[idx[dimensions]]` over every multi-index of `out`.
fn broadcast(operand: &Literal, dimensions: &[usize], out_shape: &Shape) -> Result<Literal, Error> {
    let in_dims = literal_dims(operand)?.to_vec();
    if in_dims.len() != dimensions.len() {
        return err(format!(
            "broadcast rank mismatch: operand {in_dims:?} vs dimensions {dimensions:?}"
        ));
    }
    let out_dims = &out_shape.dims;
    let out_len: i64 = out_dims.iter().product();

    // Strides of the operand, in operand-dimension order.
    let mut in_strides = vec![1i64; in_dims.len()];
    for k in (0..in_dims.len().saturating_sub(1)).rev() {
        in_strides[k] = in_strides[k + 1] * in_dims[k + 1];
    }
    // Strides of the output.
    let mut out_strides = vec![1i64; out_dims.len()];
    for k in (0..out_dims.len().saturating_sub(1)).rev() {
        out_strides[k] = out_strides[k + 1] * out_dims[k + 1];
    }

    let src_index = |flat: i64| -> usize {
        let mut idx = 0i64;
        for (k, &d) in dimensions.iter().enumerate() {
            let coord = (flat / out_strides[d]) % out_dims[d];
            idx += coord * in_strides[k];
        }
        idx as usize
    };

    Ok(match operand {
        Literal::F32 { data, .. } => Literal::F32 {
            data: (0..out_len).map(|f| data[src_index(f)]).collect(),
            dims: out_dims.clone(),
        },
        Literal::I32 { data, .. } => Literal::I32 {
            data: (0..out_len).map(|f| data[src_index(f)]).collect(),
            dims: out_dims.clone(),
        },
        Literal::Tuple(_) => return err("cannot broadcast a tuple"),
    })
}

fn elementwise(op: EwOp, a: &Literal, b: &Literal) -> Result<Literal, Error> {
    match (a, b) {
        (Literal::F32 { data: x, dims }, Literal::F32 { data: y, .. }) => {
            if x.len() != y.len() {
                return err("elementwise operand length mismatch");
            }
            let data = x
                .iter()
                .zip(y)
                .map(|(&a, &b)| match op {
                    EwOp::Add => a + b,
                    EwOp::Sub => a - b,
                    EwOp::Mul => a * b,
                    EwOp::Div => a / b,
                    EwOp::Max => a.max(b),
                    EwOp::Min => a.min(b),
                })
                .collect();
            Ok(Literal::F32 { data, dims: dims.clone() })
        }
        (Literal::I32 { data: x, dims }, Literal::I32 { data: y, .. }) => {
            if x.len() != y.len() {
                return err("elementwise operand length mismatch");
            }
            let data = x
                .iter()
                .zip(y)
                .map(|(&a, &b)| match op {
                    EwOp::Add => a.wrapping_add(b),
                    EwOp::Sub => a.wrapping_sub(b),
                    EwOp::Mul => a.wrapping_mul(b),
                    EwOp::Div => {
                        if b == 0 {
                            0
                        } else {
                            a / b
                        }
                    }
                    EwOp::Max => a.max(b),
                    EwOp::Min => a.min(b),
                })
                .collect();
            Ok(Literal::I32 { data, dims: dims.clone() })
        }
        _ => err("elementwise operand type mismatch"),
    }
}

fn convert(operand: &Literal, shape: &Option<Shape>) -> Result<Literal, Error> {
    let dtype = match shape {
        Some(s) => s.dtype,
        None => return err("convert needs an array shape"),
    };
    Ok(match (operand, dtype) {
        (Literal::F32 { data, dims }, DType::I32) => Literal::I32 {
            data: data.iter().map(|&v| v as i32).collect(),
            dims: dims.clone(),
        },
        (Literal::I32 { data, dims }, DType::F32) => Literal::F32 {
            data: data.iter().map(|&v| v as f32).collect(),
            dims: dims.clone(),
        },
        (l, _) => l.clone(),
    })
}

// ---------------------------------------------------------------------------
// Public API mirroring the real crate
// ---------------------------------------------------------------------------

/// Raw HLO module text, as loaded from an artifact file.
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("read {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

pub struct XlaComputation {
    text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { text: proto.text.clone() }
    }
}

/// The interpreter has no device state; the client is a unit handle.
#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        let instrs = parse_module(&comp.text)?;
        Ok(PjRtLoadedExecutable { instrs })
    }
}

pub struct PjRtLoadedExecutable {
    instrs: Vec<Instr>,
}

impl PjRtLoadedExecutable {
    /// Run the ENTRY computation; mirrors the real crate's
    /// per-device-per-output nesting (`result[0][0]`).
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        let mut env: HashMap<&str, Literal> = HashMap::new();
        let mut root: Option<Literal> = None;
        for instr in &self.instrs {
            let value = match &instr.op {
                Op::Parameter(i) => match args.get(*i) {
                    Some(l) => l.borrow().clone(),
                    None => return err(format!("missing argument {i}")),
                },
                Op::Constant(vals) => materialize_constant(&instr.shape, vals)?,
                Op::Broadcast { operand, dimensions } => {
                    let src = env
                        .get(operand.as_str())
                        .ok_or_else(|| Error(format!("unknown operand '{operand}'")))?;
                    let shape = instr
                        .shape
                        .as_ref()
                        .ok_or_else(|| Error("broadcast needs an array shape".into()))?;
                    broadcast(src, dimensions, shape)?
                }
                Op::Convert { operand } => {
                    let src = env
                        .get(operand.as_str())
                        .ok_or_else(|| Error(format!("unknown operand '{operand}'")))?;
                    convert(src, &instr.shape)?
                }
                Op::Elementwise { op, lhs, rhs } => {
                    let a = env
                        .get(lhs.as_str())
                        .ok_or_else(|| Error(format!("unknown operand '{lhs}'")))?;
                    let b = env
                        .get(rhs.as_str())
                        .ok_or_else(|| Error(format!("unknown operand '{rhs}'")))?;
                    elementwise(*op, a, b)?
                }
                Op::Tuple(names) => {
                    let mut parts = Vec::with_capacity(names.len());
                    for n in names {
                        parts.push(
                            env.get(n.as_str())
                                .ok_or_else(|| Error(format!("unknown operand '{n}'")))?
                                .clone(),
                        );
                    }
                    Literal::Tuple(parts)
                }
            };
            if instr.root {
                root = Some(value.clone());
            }
            env.insert(instr.name.as_str(), value);
        }
        let root = root.ok_or_else(|| Error("no ROOT value produced".into()))?;
        Ok(vec![vec![PjRtBuffer { lit: root }]])
    }
}

/// Device buffer stand-in: the literal itself.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Ok(self.lit.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(hlo: &str, args: &[Literal]) -> Literal {
        let comp = XlaComputation { text: hlo.to_string() };
        let exe = PjRtClient.compile(&comp).expect("compile");
        let out = exe.execute::<Literal>(args).expect("execute");
        out[0][0].to_literal_sync().unwrap()
    }

    #[test]
    fn add_and_tuple() {
        let hlo = r#"
HloModule tiny, entry_computation_layout={(f32[4]{0}, f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  y = f32[4]{0} parameter(1)
  s = f32[4]{0} add(x, y)
  ROOT t = (f32[4]{0}) tuple(s)
}
"#;
        let a = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let b = Literal::vec1(&[10.0f32, 20.0, 30.0, 40.0]);
        let out = run(hlo, &[a, b]);
        let parts = out.to_tuple().unwrap();
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn broadcast_scalar_and_vector() {
        let hlo = r#"
ENTRY main {
  p = s32[2]{0} parameter(0)
  c = f32[] constant(0.5)
  b = f32[2,3]{1,0} broadcast(c), dimensions={}
  v = f32[3]{0} constant({1, 2, 3})
  w = f32[2,3]{1,0} broadcast(v), dimensions={1}
  s = f32[2,3]{1,0} add(b, w)
  ROOT t = (f32[2,3]{0}) tuple(s)
}
"#;
        let out = run(hlo, &[Literal::vec1(&[7i32, 8])]);
        let parts = out.to_tuple().unwrap();
        assert_eq!(
            parts[0].to_vec::<f32>().unwrap(),
            vec![1.5, 2.5, 3.5, 1.5, 2.5, 3.5]
        );
    }

    #[test]
    fn unsupported_op_fails_at_compile() {
        let hlo = r#"
ENTRY main {
  x = f32[4]{0} parameter(0)
  ROOT d = f32[4]{0} dot(x, x)
}
"#;
        let comp = XlaComputation { text: hlo.to_string() };
        assert!(PjRtClient.compile(&comp).is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
    }
}
