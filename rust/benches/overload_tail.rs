//! Tail latency under overload: replay timed Poisson traces through the
//! scheduler on the virtual clock, sweeping arrival rate x cache budget x
//! quantization method, and record p50/p99 TTFT and end-to-end latency plus
//! throughput and shed load (rejected/expired) per cell.
//!
//! This is the serving-side counterpart of `kernel_throughput`: instead of
//! ns/row it answers "how many concurrent users does a smaller KV cache
//! buy, and what happens to the tail when arrivals outrun capacity?". The
//! virtual clock makes every cell deterministic, so the emitted
//! `BENCH_overload.json` is diffable across PRs (see
//! `ci/check_bench_trajectory.py`), and the run *asserts* the replay
//! byte-identity contract across worker counts before timing anything.
//!
//! ```bash
//! cargo bench --bench overload_tail           # full sweep
//! cargo bench --bench overload_tail quick     # CI smoke (reduced grid)
//! ```

use innerq::coordinator::{Engine, Policy, Scheduler};
use innerq::runtime::Manifest;
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::util::json::Json;
use innerq::workload::replay::{replay, CostModel, Outcome, ReplayReport};
use innerq::workload::trace::{generate_timed, Arrival, TimedRequest, TimedTraceConfig};
use innerq::QuantMethod;

fn scheduler(dir: &std::path::Path, method: QuantMethod, budget: usize, workers: usize) -> Scheduler {
    let manifest = Manifest::load(dir).expect("fake manifest");
    let mut engine = Engine::new(manifest, method.config()).expect("engine");
    engine.set_workers(workers);
    let mut sched = Scheduler::new(engine, budget);
    sched.set_policy(Policy::Fifo);
    sched
}

fn trace_for(rate_rps: f64, n_requests: usize) -> Vec<TimedRequest> {
    generate_timed(&TimedTraceConfig {
        n_requests,
        arrival: Arrival::Poisson { rate_rps },
        seed: 2026,
        ..TimedTraceConfig::default()
    })
}

struct Cell {
    rate_rps: f64,
    budget: usize,
    method: QuantMethod,
    report: ReplayReport,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "quick");
    let n_requests: usize = args
        .iter()
        .filter_map(|a| a.parse().ok())
        .next()
        .unwrap_or(if quick { 32 } else { 96 });
    let rates: &[f64] = if quick { &[200.0, 800.0] } else { &[100.0, 300.0, 600.0, 1200.0] };
    let budgets: &[usize] =
        if quick { &[64_000, 256_000] } else { &[48_000, 128_000, 512_000] };
    let methods: &[QuantMethod] = if quick {
        &[QuantMethod::InnerQBase, QuantMethod::BaselineFp16]
    } else {
        &[QuantMethod::InnerQBase, QuantMethod::Kivi, QuantMethod::BaselineFp16]
    };
    let cost = CostModel::default();
    let dir = write_fake_artifacts("overload_tail", '7');

    eprintln!(
        "[overload_tail] {n_requests} requests/cell, {} rates x {} budgets x {} methods, quick={quick}",
        rates.len(),
        budgets.len(),
        methods.len()
    );

    // Determinism contract first: the replay report must be byte-identical
    // across worker counts (any panic or mismatch fails CI).
    {
        let trace = trace_for(rates[0], n_requests);
        let mut s1 = scheduler(&dir, QuantMethod::InnerQBase, budgets[0], 1);
        let mut s2 = scheduler(&dir, QuantMethod::InnerQBase, budgets[0], 2);
        let a = replay(&mut s1, &trace, &cost).expect("replay w1").to_json().dump();
        let b = replay(&mut s2, &trace, &cost).expect("replay w2").to_json().dump();
        assert_eq!(a, b, "replay byte-identity violated between workers=1 and workers=2");
        eprintln!("[overload_tail] determinism contract holds (workers 1 vs 2)");
    }

    println!(
        "{:<14} {:>8} {:>9} {:>5} {:>5} {:>5} {:>8} {:>10} {:>10} {:>10} {:>10}",
        "method", "rate", "budget", "ok", "rej", "exp", "req/s", "ttft p50", "ttft p99",
        "e2e p50", "e2e p99"
    );
    let mut cells: Vec<Cell> = Vec::new();
    for &rate in rates {
        let trace = trace_for(rate, n_requests);
        for &budget in budgets {
            for &method in methods {
                let mut sched = scheduler(&dir, method, budget, 1);
                let report = replay(&mut sched, &trace, &cost).expect("replay");
                let o = report.overall();
                let (t, e) = (o.ttft.summary(), o.e2e.summary());
                println!(
                    "{:<14} {:>8.0} {:>9} {:>5} {:>5} {:>5} {:>8.1} {:>9}µ {:>9}µ {:>9}µ {:>9}µ",
                    method.name(),
                    rate,
                    budget,
                    report.count(Outcome::Ok),
                    report.count(Outcome::Rejected),
                    report.count(Outcome::Expired),
                    report.throughput_rps(),
                    t.p50_us,
                    t.p99_us,
                    e.p50_us,
                    e.p99_us,
                );
                cells.push(Cell { rate_rps: rate, budget, method, report });
            }
        }
    }

    // Machine-readable trajectory record (summaries only — the per-request
    // records would dwarf the file at full-sweep sizes).
    let results: Vec<Json> = cells
        .iter()
        .map(|c| {
            let o = c.report.overall();
            let (t, e) = (o.ttft.summary(), o.e2e.summary());
            Json::obj(vec![
                ("method", Json::str(c.method.name())),
                ("rate_rps", Json::Num(c.rate_rps)),
                ("budget_bytes", Json::Num(c.budget as f64)),
                ("n_requests", Json::Num(c.report.records.len() as f64)),
                ("completed", Json::Num(c.report.count(Outcome::Ok) as f64)),
                ("rejected", Json::Num(c.report.count(Outcome::Rejected) as f64)),
                ("expired", Json::Num(c.report.count(Outcome::Expired) as f64)),
                ("preemptions", Json::Num(c.report.metrics.preemptions as f64)),
                ("throughput_rps", Json::Num(c.report.throughput_rps())),
                ("gen_tokens_per_s", Json::Num(c.report.gen_tokens_per_s())),
                ("ttft_p50_us", Json::Num(t.p50_us as f64)),
                ("ttft_p99_us", Json::Num(t.p99_us as f64)),
                ("e2e_p50_us", Json::Num(e.p50_us as f64)),
                ("e2e_p99_us", Json::Num(e.p99_us as f64)),
                ("virtual_us", Json::Num(c.report.end_us as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("overload_tail")),
        ("quick", Json::Bool(quick)),
        ("n_requests", Json::Num(n_requests as f64)),
        ("policy", Json::str("fifo")),
        ("results", Json::Arr(results)),
    ]);
    let path = "BENCH_overload.json";
    std::fs::write(path, doc.dump()).expect("write BENCH_overload.json");
    eprintln!("[overload_tail] wrote {path}");
}
