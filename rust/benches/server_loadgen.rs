//! Socket-path load generator over the staged server front end.
//!
//! Every other bench in the tree drives the scheduler directly; this one
//! drives the *real* staged pipeline — listener, IO workers, SPSC queues,
//! driver — over real sockets with a timed trace, and uses the virtual-clock
//! replay harness as its determinism oracle: before any timing is recorded,
//! the per-request completion text coming back over the wire must be
//! byte-identical to `workload::replay`'s text for the same trace, at every
//! `--io-workers` count in the sweep. The trace is greedy (no temperature)
//! and deadline-free with an ample cache budget, so completion text is a
//! pure function of each prompt — any difference between socket and replay
//! (or between io-worker counts) is a server bug, not scheduling noise.
//!
//! Clients pipeline requests over a few connections, paced to the trace's
//! arrival times, and match completions by the echoed `tag` field (the
//! server assigns its own ids).
//!
//! One cell per rate additionally runs with the tracing plane armed
//! ([`innerq::obs`]) and the admin listener up: it must pass the *same*
//! byte-identity oracle (tracing cannot perturb output), its wall-clock
//! delta against the matching untraced cell lands in `BENCH_server.json`
//! as the tracing-overhead guard, and the admin `metrics` page it scrapes
//! is written to `METRICS.prom` for `ci/check_prometheus.py`.
//!
//! ```bash
//! cargo bench --bench server_loadgen           # full sweep
//! cargo bench --bench server_loadgen quick     # CI smoke
//! ```

use innerq::coordinator::{Engine, Scheduler};
use innerq::runtime::Manifest;
use innerq::server::{serve_with, AdminClient, ServerConfig};
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::util::json::Json;
use innerq::util::stats::LatencyHistogram;
use innerq::workload::replay::{replay, CostModel, Outcome};
use innerq::workload::trace::{generate_timed, Arrival, TimedRequest, TimedTraceConfig};
use innerq::QuantMethod;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Ample budget: every request admits and completes, so the oracle contract
/// is pure text determinism (overload behavior is `overload_tail`'s job).
const BUDGET: usize = 1 << 30;
const SEED: u64 = 2026;
const METHOD: QuantMethod = QuantMethod::InnerQBase;
/// Client connections the trace is dealt over (round-robin).
const N_CONNS: usize = 4;

fn trace(rate_rps: f64, n_requests: usize) -> Vec<TimedRequest> {
    generate_timed(&TimedTraceConfig {
        n_requests,
        arrival: Arrival::Poisson { rate_rps },
        seed: SEED,
        ..TimedTraceConfig::default()
    })
}

fn scheduler(dir: &std::path::Path) -> Scheduler {
    let manifest = Manifest::load(dir).expect("fake manifest");
    let mut engine = Engine::new(manifest, METHOD.config()).expect("engine");
    engine.set_workers(2);
    Scheduler::new(engine, BUDGET)
}

/// The replay oracle: per-request completion text keyed by trace id. The
/// whole trace must complete `Ok` — anything else means the bench is
/// misconfigured and the identity contract would be vacuous.
fn oracle_texts(dir: &std::path::Path, trace: &[TimedRequest]) -> HashMap<u64, String> {
    let mut sched = scheduler(dir);
    let report = replay(&mut sched, trace, &CostModel::default()).expect("oracle replay");
    assert_eq!(
        report.count(Outcome::Ok),
        trace.len(),
        "oracle replay must complete every request (got {} of {})",
        report.count(Outcome::Ok),
        trace.len()
    );
    report.records.iter().map(|r| (r.id, r.text.clone())).collect()
}

struct CellResult {
    wall_ms: f64,
    throughput_rps: f64,
    e2e: LatencyHistogram,
    ttft: LatencyHistogram,
}

/// The io-worker count the per-rate traced cell runs at (the middle of the
/// sweep: tracing overhead should be measured on a representative shape).
const TRACED_IO_WORKERS: usize = 2;

fn cell_row(
    cell: &CellResult,
    io_workers: usize,
    rate: f64,
    n_requests: usize,
    traced: bool,
    overhead_pct: Option<f64>,
) -> Json {
    let (t, e) = (cell.ttft.summary(), cell.e2e.summary());
    let mut fields = vec![
        ("method", Json::str(METHOD.name())),
        ("io_workers", Json::Num(io_workers as f64)),
        ("rate_rps", Json::Num(rate)),
        ("n_requests", Json::Num(n_requests as f64)),
        ("n_conns", Json::Num(N_CONNS as f64)),
        ("traced", Json::Bool(traced)),
        ("wall_ms", Json::Num(cell.wall_ms)),
        ("throughput_rps", Json::Num(cell.throughput_rps)),
        ("ttft_p50_us", Json::Num(t.p50_us as f64)),
        ("ttft_p99_us", Json::Num(t.p99_us as f64)),
        ("e2e_p50_us", Json::Num(e.p50_us as f64)),
        ("e2e_p99_us", Json::Num(e.p99_us as f64)),
    ];
    if let Some(pct) = overhead_pct {
        fields.push(("trace_overhead_pct", Json::Num(pct)));
    }
    Json::obj(fields)
}

/// Run the trace through a live staged server at `io_workers`, assert the
/// socket completions match the oracle byte-for-byte, and return the wire
/// timings. With `traced`, the whole cell runs with the tracing plane armed
/// and the admin plane up, and the admin `metrics` page is scraped into
/// `METRICS.prom` while the server is still live — same oracle contract, so
/// this is the bench-level proof that tracing never changes output bytes.
fn run_cell(
    dir: &std::path::Path,
    trace: &[TimedRequest],
    io_workers: usize,
    oracle: &HashMap<u64, String>,
    traced: bool,
) -> CellResult {
    let _guard = traced.then(innerq::obs::TraceGuard::arm);
    let sched = scheduler(dir);
    let stop = Arc::new(AtomicBool::new(false));
    let stop_srv = stop.clone();
    let (addr_tx, addr_rx) = mpsc::channel();
    let admin_addr = traced.then(|| "127.0.0.1:0".to_string());
    let server = std::thread::spawn(move || {
        serve_with(
            sched,
            "127.0.0.1:0",
            ServerConfig { io_workers, admin_addr },
            stop_srv,
            move |b| {
                let _ = addr_tx.send((b.data, b.admin));
            },
        )
        .expect("serve_with")
    });
    let (addr, admin) = addr_rx.recv().expect("server bound");

    // Deal the trace over the client connections round-robin, keeping each
    // request's absolute send time.
    let n_conns = N_CONNS.min(trace.len()).max(1);
    let mut batches: Vec<Vec<(u64, String)>> = vec![Vec::new(); n_conns];
    for (i, t) in trace.iter().enumerate() {
        let line = Json::obj(vec![
            ("prompt", Json::str(&t.req.prompt)),
            ("max_new_tokens", Json::Num(t.req.max_new_tokens as f64)),
            ("tag", Json::str(&t.req.id.to_string())),
        ])
        .dump();
        batches[i % n_conns].push((t.arrival_us, line));
    }

    let t0 = Instant::now();
    let clients: Vec<_> = batches
        .into_iter()
        .map(|batch| {
            std::thread::spawn(move || {
                let mut conn = TcpStream::connect(addr).expect("connect");
                let mut reader = BufReader::new(conn.try_clone().expect("clone"));
                for (at_us, line) in &batch {
                    let target = Duration::from_micros(*at_us);
                    let since = t0.elapsed();
                    if target > since {
                        std::thread::sleep(target - since);
                    }
                    writeln!(conn, "{line}").expect("send");
                }
                let mut lines = Vec::with_capacity(batch.len());
                for _ in 0..batch.len() {
                    let mut s = String::new();
                    let n = reader.read_line(&mut s).expect("read");
                    assert!(n > 0, "server closed mid-trace");
                    lines.push(s);
                }
                lines
            })
        })
        .collect();
    let mut responses: Vec<String> = Vec::new();
    for c in clients {
        responses.extend(c.join().expect("client thread"));
    }
    let wall = t0.elapsed();
    if traced {
        // Scrape the Prometheus page from the live server so CI can lint
        // the exposition format (ci/check_prometheus.py).
        let admin = admin.expect("traced cell has an admin plane");
        let mut ac = AdminClient::connect(admin).expect("admin connect");
        let page = ac.metrics().expect("metrics scrape");
        assert!(
            page.contains("# TYPE innerq_decode_steps gauge"),
            "metrics page missing expected series:\n{page}"
        );
        std::fs::write("METRICS.prom", &page).expect("write METRICS.prom");
        eprintln!("[server_loadgen] scraped {} metric lines to METRICS.prom", page.lines().count());
    }
    stop.store(true, Ordering::Relaxed);
    server.join().expect("server thread");

    // Identity contract FIRST: socket text == oracle text, per request,
    // before this cell contributes any timing.
    let mut got: HashMap<u64, String> = HashMap::new();
    let mut e2e = LatencyHistogram::new();
    let mut ttft = LatencyHistogram::new();
    for line in &responses {
        let j = Json::parse(line).expect("response line parses");
        assert!(
            matches!(j.get("error"), Json::Null),
            "unexpected in-band error: {line}"
        );
        let tag: u64 = j.get("tag").as_str().expect("tag echoed").parse().expect("tag");
        got.insert(tag, j.get("text").as_str().unwrap_or("").to_string());
        e2e.record(j.get("total_us").as_f64().unwrap_or(0.0) as u64);
        ttft.record(j.get("ttft_us").as_f64().unwrap_or(0.0) as u64);
    }
    assert_eq!(got.len(), trace.len(), "every request must complete exactly once");
    for t in trace {
        let want = &oracle[&t.req.id];
        let have = got.get(&t.req.id).expect("completion for trace id");
        assert_eq!(
            have, want,
            "io_workers={io_workers}: socket completion for request {} diverged from the \
             replay oracle",
            t.req.id
        );
    }

    let wall_s = wall.as_secs_f64().max(1e-9);
    CellResult {
        wall_ms: wall_s * 1e3,
        throughput_rps: trace.len() as f64 / wall_s,
        e2e,
        ttft,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "quick");
    let n_requests: usize = args
        .iter()
        .filter_map(|a| a.parse().ok())
        .next()
        .unwrap_or(if quick { 24 } else { 64 });
    let rates: &[f64] = if quick { &[300.0] } else { &[100.0, 400.0] };
    // Every io-worker count must pass the oracle contract, quick mode
    // included — this is the acceptance gate, not a timing nicety.
    let io_worker_counts: &[usize] = &[1, 2, 4];
    let dir = write_fake_artifacts("server_loadgen", '7');

    eprintln!(
        "[server_loadgen] {n_requests} requests/cell over {N_CONNS} conns, rates {rates:?}, \
         io-workers {io_worker_counts:?}, method={}, quick={quick}",
        METHOD.name()
    );

    let mut results: Vec<Json> = Vec::new();
    for &rate in rates {
        let tr = trace(rate, n_requests);
        let oracle = oracle_texts(&dir, &tr);
        eprintln!(
            "[server_loadgen] rate={rate}: oracle replay complete ({} requests)",
            oracle.len()
        );
        let mut untraced_wall_2w = None;
        for &io_workers in io_worker_counts {
            let cell = run_cell(&dir, &tr, io_workers, &oracle, false);
            eprintln!(
                "[server_loadgen] rate={rate} io_workers={io_workers}: oracle identity holds; \
                 {:.1} req/s wall={:.0}ms",
                cell.throughput_rps, cell.wall_ms
            );
            if io_workers == TRACED_IO_WORKERS {
                untraced_wall_2w = Some(cell.wall_ms);
            }
            results.push(cell_row(&cell, io_workers, rate, n_requests, false, None));
        }
        // Tracing-overhead guard: the same trace with the plane armed must
        // still pass the byte-identity oracle, and its wall-clock delta is
        // recorded for the trajectory check.
        let traced = run_cell(&dir, &tr, TRACED_IO_WORKERS, &oracle, true);
        let overhead_pct = untraced_wall_2w
            .map(|base| (traced.wall_ms - base) / base.max(1e-9) * 100.0);
        eprintln!(
            "[server_loadgen] rate={rate} io_workers={TRACED_IO_WORKERS} traced: oracle \
             identity holds; {:.1} req/s wall={:.0}ms overhead={:+.1}%",
            traced.throughput_rps,
            traced.wall_ms,
            overhead_pct.unwrap_or(0.0)
        );
        results.push(cell_row(&traced, TRACED_IO_WORKERS, rate, n_requests, true, overhead_pct));
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("server_loadgen")),
        ("quick", Json::Bool(quick)),
        ("n_requests", Json::Num(n_requests as f64)),
        ("budget_bytes", Json::Num(BUDGET as f64)),
        ("results", Json::Arr(results)),
    ]);
    let path = "BENCH_server.json";
    std::fs::write(path, doc.dump()).expect("write BENCH_server.json");
    eprintln!("[server_loadgen] wrote {path}");
}
