//! Table 6: latency of the fused hybrid dequantize-GEMV (Eq. 5 value op)
//! as a function of the sparsity of the mode mask M (§6.2). Mask density is
//! forced by flipping the asym flag on a controlled fraction of groups; the
//! kernel's per-group branch goes from perfectly predicted (99% sparse) to
//! maximally mispredicted (~50%).
//!
//! ```bash
//! cargo bench --bench table6_sparsity
//! ```

mod common;

use common::*;
use innerq::cache::segments::InnerValSegment;
use innerq::quant::group::Mode;
use innerq::util::fp16::f32_to_f16_bits;
use innerq::util::rng::Rng;
use innerq::util::stats::time_us;

/// Force the asym-flag density of a hybrid value segment.
fn force_density(seg: &mut InnerValSegment, frac_asym: f64, rng: &mut Rng) {
    for p in seg.params.iter_mut() {
        let make_asym = (rng.next_f32() as f64) < frac_asym;
        let mag = p.scale & 0x7fff;
        if make_asym {
            p.scale = mag | 0x8000;
            // a zero-point consistent with a real asym group (small shift)
            p.zero = f32_to_f16_bits(-0.01);
        } else {
            p.scale = mag;
            p.zero = 0;
        }
    }
}

fn main() {
    let lengths = [1024usize, 4096, 16384, 32768];
    let sparsities = [0.99f64, 0.90, 0.50, 0.01];

    println!("Table 6 (measured, CPU): fused hybrid dequant-GEMV value-op latency (µs)");
    println!(
        "{:<12} {}",
        "sparsity",
        lengths.iter().map(|n| format!("{n:>9}")).collect::<String>()
    );

    for &sp in &sparsities {
        let mut cells = Vec::new();
        for &n in &lengths {
            let d = layer_data(n, 5);
            let mut rng = Rng::new(1000 + (sp * 100.0) as u64);
            let mut segs: Vec<InnerValSegment> = Vec::new();
            for h in 0..N_KV {
                let mut seg = InnerValSegment::new(D_H, 2, Mode::Hybrid);
                for chunk in d.vals[h].chunks_exact(32 * D_H) {
                    seg.append_chunk(chunk);
                }
                force_density(&mut seg, 1.0 - sp, &mut rng);
                segs.push(seg);
            }
            let mut ctx = vec![0f32; D_H];
            let (w, r) = reps_for(n);
            let rep = N_Q / N_KV;
            let s = time_us(w, r, || {
                for seg in &segs {
                    for _ in 0..rep {
                        ctx.iter_mut().for_each(|v| *v = 0.0);
                        seg.accumulate(&d.p, &mut ctx);
                    }
                }
                ctx[0]
            });
            cells.push(s.mean_us);
        }
        println!(
            "{:<12} {}",
            format!("{:.0}%", sp * 100.0),
            cells.iter().map(|x| format!("{x:>9.0}")).collect::<String>()
        );
    }
    println!("\n(paper Table 6: 99% sparse fastest; latency rises as M densifies, but even at 1% \
              sparsity stays below KIVI/TurboQuant)");
}
