//! Prefix sharing under the multi-turn overload sweep: replay the same
//! chat-style trace (requests round-robined over sessions that reuse one
//! context prefix) with the content-addressed prefix store on and off, per
//! quantization method, and record throughput, tail latency, admitted
//! concurrency, and the hit/shared-byte traffic — the harness that answers
//! "does CoW sharing of quantized prefixes buy real concurrency at a fixed
//! cache budget?". A single-turn control family (no shared prefixes) rides
//! along so the store's overhead on unshareable traffic is visible.
//!
//! The methods run with their paper bit-widths but *small* high-precision
//! windows (sink 4 + recent 8): with the default 128-token window the fake
//! model's bucket-sized prompts never quantize their prefix, and there
//! would be nothing to share.
//!
//! Before timing anything the run asserts three contracts (any panic fails
//! CI):
//!   * bit-identity — decoding against a borrowed quantized prefix is
//!     byte-identical (logits bits and serialized caches) to the private
//!     split-norm path, per method, workers 1 and 2;
//!   * replay byte-identity — the share-on multi-turn replay report is
//!     identical between workers=1 and workers=2;
//!   * concurrency — sharing strictly increases the maximum number of
//!     simultaneously admitted requests on the multi-turn trace.
//!
//! ```bash
//! cargo bench --bench prefix_sharing           # full sweep
//! cargo bench --bench prefix_sharing quick     # CI smoke
//! ```

use innerq::cache::store::PrefixStore;
use innerq::coordinator::{Engine, Policy, PrefixOutcome, Scheduler};
use innerq::quant::MethodConfig;
use innerq::runtime::Manifest;
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::util::json::Json;
use innerq::workload::replay::{replay, CostModel, Outcome, ReplayReport};
use innerq::workload::trace::{
    generate_multi_turn, generate_timed, Arrival, MultiTurnTraceConfig, TimedRequest,
    TimedTraceConfig,
};
use innerq::QuantMethod;

/// Tight budget (a handful of concurrent sequences at the fake geometry) so
/// admission control is the binding constraint sharing relaxes.
const BUDGET: usize = 64_000;
const SEED: u64 = 2026;

/// Paper bit-widths, serving-sized windows (see module docs).
fn serving_cfg(method: QuantMethod) -> MethodConfig {
    let mut cfg = method.config();
    cfg.w_sink = cfg.w_sink.min(4);
    cfg.w_recent = cfg.w_recent.min(8).max(4);
    cfg
}

fn scheduler(dir: &std::path::Path, cfg: MethodConfig, workers: usize, share: bool) -> Scheduler {
    let manifest = Manifest::load(dir).expect("fake manifest");
    let mut engine = Engine::new(manifest, cfg).expect("engine");
    engine.set_workers(workers);
    let mut sched = Scheduler::new(engine, BUDGET);
    sched.set_policy(Policy::Slo);
    sched.set_prefix_share(share);
    sched
}

/// Chat-style family: long shared session prefixes, short per-turn suffixes.
fn multi_turn_trace(rate_rps: f64, n_requests: usize) -> Vec<TimedRequest> {
    generate_multi_turn(&MultiTurnTraceConfig {
        base: TimedTraceConfig {
            n_requests,
            arrival: Arrival::Poisson { rate_rps },
            vars_range: (2, 4),
            seed: SEED,
            ..TimedTraceConfig::default()
        },
        n_sessions: 4,
        prefix_vars: 20,
    })
}

/// Control family: independent prompts, nothing shareable.
fn single_turn_trace(rate_rps: f64, n_requests: usize) -> Vec<TimedRequest> {
    generate_timed(&TimedTraceConfig {
        n_requests,
        arrival: Arrival::Poisson { rate_rps },
        seed: SEED,
        ..TimedTraceConfig::default()
    })
}

/// Maximum number of requests simultaneously resident in the decode batch:
/// the peak overlap of the completed records' [admitted, finished] spans.
fn max_admitted_concurrency(report: &ReplayReport) -> usize {
    let mut deltas: Vec<(u64, i64)> = Vec::new();
    for r in &report.records {
        if r.outcome != Some(Outcome::Ok) {
            continue;
        }
        let (Some(a), Some(f)) = (r.admitted_us, r.finished_us) else { continue };
        deltas.push((a, 1));
        deltas.push((f.max(a) + 1, -1));
    }
    deltas.sort_unstable();
    let mut cur = 0i64;
    let mut best = 0i64;
    for (_, d) in deltas {
        cur += d;
        best = best.max(cur);
    }
    best.max(0) as usize
}

/// Bit-identity contract: per method, decode three shared-prefix prompts
/// through the store (publish + borrow) and privately, workers 1 and 2 —
/// logits bit patterns and serialized caches must match exactly.
fn assert_bit_identity_contract(dir: &std::path::Path, methods: &[QuantMethod]) {
    const PREFIX: &str = "a=13;b=88;c=07;d=55;e=21;f=99;";
    const SUFFIXES: [&str; 3] = ["g=42;h=10;?a=", "i=64;j=27;?c=", "?e="];
    const STEPS: usize = 24;

    fn run(
        dir: &std::path::Path,
        cfg: MethodConfig,
        workers: usize,
        mut store: Option<&mut PrefixStore>,
    ) -> (Vec<u32>, Vec<Vec<u8>>) {
        use innerq::cache::store::snapshot_sequence;
        let manifest = Manifest::load(dir).expect("fake manifest");
        let mut engine = Engine::new(manifest, cfg).expect("engine");
        engine.set_workers(workers);
        let mut seqs: Vec<_> = SUFFIXES
            .iter()
            .map(|s| {
                let prompt = format!("{PREFIX}{s}");
                let tokens = engine.manifest.encode(&prompt).expect("encode");
                engine
                    .prefill_shared(&tokens, PREFIX.len(), store.as_deref_mut())
                    .expect("prefill")
                    .0
            })
            .collect();
        let mut bits: Vec<u32> = Vec::new();
        for _ in 0..STEPS {
            let next: Vec<i32> = seqs.iter().map(|s| Engine::argmax(&s.last_logits)).collect();
            let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
            engine.decode_step(&mut refs, &next).expect("decode");
            for s in refs.iter() {
                bits.extend(s.last_logits.iter().map(|v| v.to_bits()));
            }
        }
        let caches: Vec<Vec<u8>> = seqs.iter().map(snapshot_sequence).collect();
        (bits, caches)
    }

    for &method in methods {
        let cfg = serving_cfg(method);
        let reference = run(dir, cfg, 1, None);
        for workers in [1usize, 2] {
            let mut store = PrefixStore::new(1 << 20);
            let shared = run(dir, cfg, workers, Some(&mut store));
            assert_eq!(
                shared, reference,
                "{}: shared-prefix decode diverged from private (workers={workers})",
                method.name()
            );
            let private = run(dir, cfg, workers, None);
            assert_eq!(
                private, reference,
                "{}: private decode diverged across workers={workers}",
                method.name()
            );
        }
        // And the store actually dedups: a second engine-level borrow hits.
        let mut store = PrefixStore::new(1 << 20);
        let manifest = Manifest::load(dir).expect("fake manifest");
        let engine = Engine::new(manifest, cfg).expect("engine");
        let prompt = format!("{PREFIX}{}", SUFFIXES[0]);
        let tokens = engine.manifest.encode(&prompt).expect("encode");
        let (_, first) = engine
            .prefill_shared(&tokens, PREFIX.len(), Some(&mut store))
            .expect("publish");
        let (_, second) = engine
            .prefill_shared(&tokens, PREFIX.len(), Some(&mut store))
            .expect("borrow");
        assert!(matches!(first, PrefixOutcome::Published { .. }), "{}: {first:?}", method.name());
        assert!(matches!(second, PrefixOutcome::Hit { .. }), "{}: {second:?}", method.name());
    }
    eprintln!("[prefix_sharing] bit-identity contract holds ({} methods)", methods.len());
}

struct Cell {
    family: &'static str,
    method: QuantMethod,
    share: bool,
    rate_rps: f64,
    concurrency: usize,
    report: ReplayReport,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "quick");
    let n_requests: usize = args
        .iter()
        .filter_map(|a| a.parse().ok())
        .next()
        .unwrap_or(if quick { 48 } else { 96 });
    let rate = 2000.0; // far past capacity: admission control binds
    let methods: &[QuantMethod] = if quick {
        &[QuantMethod::InnerQBase]
    } else {
        &[QuantMethod::InnerQBase, QuantMethod::InnerQHybrid, QuantMethod::Kivi]
    };
    let cost = CostModel::default();
    let dir = write_fake_artifacts("prefix_sharing", '7');

    eprintln!(
        "[prefix_sharing] {n_requests} requests/cell, {} methods x 2 families x share on/off, \
         budget={BUDGET}, quick={quick}",
        methods.len()
    );

    assert_bit_identity_contract(&dir, methods);

    // Replay byte-identity with the store in the loop.
    {
        let trace = multi_turn_trace(rate, n_requests);
        let mut s1 = scheduler(&dir, serving_cfg(QuantMethod::InnerQBase), 1, true);
        let mut s2 = scheduler(&dir, serving_cfg(QuantMethod::InnerQBase), 2, true);
        let a = replay(&mut s1, &trace, &cost).expect("replay w1");
        let b = replay(&mut s2, &trace, &cost).expect("replay w2");
        assert_eq!(
            a.to_json().dump(),
            b.to_json().dump(),
            "share-on replay byte-identity violated between workers=1 and workers=2"
        );
        eprintln!(
            "[prefix_sharing] determinism contract holds (workers 1 vs 2, {} prefix hits)",
            a.metrics.prefix_hits
        );
    }

    // Concurrency contract: sharing must strictly raise peak admitted
    // concurrency on the multi-turn trace, per method — asserted before any
    // cell is recorded.
    let families: [(&'static str, fn(f64, usize) -> Vec<TimedRequest>); 2] =
        [("multi_turn", multi_turn_trace), ("single_turn", single_turn_trace)];
    let mut cells: Vec<Cell> = Vec::new();
    for &method in methods {
        let cfg = serving_cfg(method);
        for (family, gen) in families {
            let trace = gen(rate, n_requests);
            let mut by_share = [0usize; 2];
            for share in [false, true] {
                let mut sched = scheduler(&dir, cfg, 1, share);
                let report = replay(&mut sched, &trace, &cost).expect("replay");
                let concurrency = max_admitted_concurrency(&report);
                by_share[usize::from(share)] = concurrency;
                cells.push(Cell { family, method, share, rate_rps: rate, concurrency, report });
            }
            if family == "multi_turn" {
                assert!(
                    by_share[1] > by_share[0],
                    "{}: sharing must strictly increase admitted concurrency \
                     (on={} vs off={})",
                    method.name(),
                    by_share[1],
                    by_share[0]
                );
            }
        }
    }

    println!(
        "{:<14} {:<12} {:>6} {:>5} {:>5} {:>7} {:>10} {:>8} {:>10} {:>10}",
        "method", "family", "share", "ok", "conc", "hits", "shared_kb", "req/s", "e2e p50",
        "e2e p99"
    );
    for c in &cells {
        let e = c.report.overall().e2e.summary();
        println!(
            "{:<14} {:<12} {:>6} {:>5} {:>5} {:>7} {:>10.1} {:>8.1} {:>9}µ {:>9}µ",
            c.method.name(),
            c.family,
            if c.share { "on" } else { "off" },
            c.report.count(Outcome::Ok),
            c.concurrency,
            c.report.metrics.prefix_hits,
            c.report.metrics.prefix_bytes_shared as f64 / 1024.0,
            c.report.throughput_rps(),
            e.p50_us,
            e.p99_us,
        );
    }

    let results: Vec<Json> = cells
        .iter()
        .map(|c| {
            let o = c.report.overall();
            let (t, e) = (o.ttft.summary(), o.e2e.summary());
            Json::obj(vec![
                ("family", Json::str(c.family)),
                ("method", Json::str(c.method.name())),
                ("prefix_share", Json::Bool(c.share)),
                ("rate_rps", Json::Num(c.rate_rps)),
                ("budget_bytes", Json::Num(BUDGET as f64)),
                ("n_requests", Json::Num(c.report.records.len() as f64)),
                ("completed", Json::Num(c.report.count(Outcome::Ok) as f64)),
                ("rejected", Json::Num(c.report.count(Outcome::Rejected) as f64)),
                ("max_concurrency", Json::Num(c.concurrency as f64)),
                ("prefix_hits", Json::Num(c.report.metrics.prefix_hits as f64)),
                (
                    "prefix_bytes_shared",
                    Json::Num(c.report.metrics.prefix_bytes_shared as f64),
                ),
                ("throughput_rps", Json::Num(c.report.throughput_rps())),
                ("gen_tokens_per_s", Json::Num(c.report.gen_tokens_per_s())),
                ("ttft_p50_us", Json::Num(t.p50_us as f64)),
                ("ttft_p99_us", Json::Num(t.p99_us as f64)),
                ("e2e_p50_us", Json::Num(e.p50_us as f64)),
                ("e2e_p99_us", Json::Num(e.p99_us as f64)),
                ("virtual_us", Json::Num(c.report.end_us as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("prefix_sharing")),
        ("quick", Json::Bool(quick)),
        ("n_requests", Json::Num(n_requests as f64)),
        ("policy", Json::str("slo")),
        ("budget_bytes", Json::Num(BUDGET as f64)),
        ("results", Json::Arr(results)),
    ]);
    let path = "BENCH_prefix.json";
    std::fs::write(path, doc.dump()).expect("write BENCH_prefix.json");
    eprintln!("[prefix_sharing] wrote {path}");
}
