//! Table 5: per-decode-step quantization overhead (µs) for one
//! Llama-3.1-8B layer, following each method's eviction cadence (§5.3):
//! InnerQ quantizes 1 key token/step and a 32-token value chunk every 32
//! steps (amortized ÷32); KIVI is mirrored; TurboQuant does 1+1 every step.
//!
//! ```bash
//! cargo bench --bench table5_quant
//! ```

mod common;

use common::*;
use innerq::cache::segments::*;
use innerq::quant::group::Mode;
use innerq::util::rng::Rng;
use innerq::util::stats::time_us;

fn main() {
    let mut rng = Rng::new(99);
    let token: Vec<f32> = rand_vec(&mut rng, D_H);
    let chunk: Vec<f32> = rand_vec(&mut rng, 32 * D_H);
    let (w, r) = (10, 100);

    // Per step and per KV head; report per layer (x N_KV).
    let innerq_key = time_us(w, r, || {
        let mut seg = InnerKeySegment::new(D_H, 3, Mode::Sym);
        for _ in 0..N_KV {
            seg.append_token(&token);
        }
        seg.len()
    })
    .mean_us;

    let innerq_val = time_us(w, r, || {
        let mut seg = InnerValSegment::new(D_H, 3, Mode::Sym);
        for _ in 0..N_KV {
            seg.append_chunk(&chunk);
        }
        seg.len()
    })
    .mean_us
        / 32.0; // amortized: one chunk per 32 steps

    let innerq_val_hybrid = time_us(w, r, || {
        let mut seg = InnerValSegment::new(D_H, 2, Mode::Hybrid);
        for _ in 0..N_KV {
            seg.append_chunk(&chunk);
        }
        seg.len()
    })
    .mean_us
        / 32.0;

    let kivi_key = time_us(w, r, || {
        let mut seg = OuterKeySegment::new(D_H, 2, Mode::Asym);
        for _ in 0..N_KV {
            seg.append_chunk(&chunk);
        }
        seg.len()
    })
    .mean_us
        / 32.0;

    let kivi_val = time_us(w, r, || {
        let mut seg = OuterValSegment::new(D_H, 2, Mode::Asym);
        for _ in 0..N_KV {
            seg.append_token(&token);
        }
        seg.len()
    })
    .mean_us;

    let turbo_key = time_us(w, r, || {
        let mut seg = TurboKeySegment::new(D_H, 4, 42);
        for _ in 0..N_KV {
            seg.append_token(&token);
        }
        seg.len()
    })
    .mean_us;

    let turbo_val = time_us(w, r, || {
        let mut seg = TurboValSegment::new(D_H, 3, 43);
        for _ in 0..N_KV {
            seg.append_token(&token);
        }
        seg.len()
    })
    .mean_us;

    println!("Table 5 (measured, CPU): per-step quantization overhead (µs), one layer, 8 KV heads");
    println!("{:<16} {:>10} {:>12} {:>10}", "method", "key", "value", "total");
    println!(
        "{:<16} {:>10.1} {:>12.1} {:>10.1}",
        "kivi", kivi_key, kivi_val, kivi_key + kivi_val
    );
    println!(
        "{:<16} {:>10.1} {:>12.1} {:>10.1}",
        "turboquant", turbo_key, turbo_val, turbo_key + turbo_val
    );
    println!(
        "{:<16} {:>10.1} {:>12.1} {:>10.1}",
        "innerq_base", innerq_key, innerq_val, innerq_key + innerq_val
    );
    println!(
        "{:<16} {:>10.1} {:>12.1} {:>10.1}",
        "innerq_hybrid", innerq_key, innerq_val_hybrid, innerq_key + innerq_val_hybrid
    );
    println!(
        "{:<16} {:>10.1} {:>12.1} {:>10.1}",
        "innerq_small", innerq_key, innerq_val, innerq_key + innerq_val
    );
    println!("\n(paper Table 5: KIVI 22.1, TurboQuant 31.9, InnerQ 18.2-18.7 µs — \
              shape target: InnerQ < KIVI < TurboQuant)");
}
