#![allow(dead_code)] // shared across bench targets; each uses a subset

//! Shared bench helpers: build quantized segments at Table-4 geometry
//! (one Llama-3.1-8B layer: 32 query heads, 8 KV heads, d_h = 128) and
//! time with the paper's protocol (10 warmup + 100 reps, scaled down for
//! very long sequences on this single-core testbed).

use innerq::cache::segments::*;
use innerq::quant::group::Mode;
use innerq::util::rng::Rng;

pub const D_H: usize = 128;
pub const N_KV: usize = 8;
pub const N_Q: usize = 32;
pub const LENGTHS: [usize; 7] = [512, 1024, 2048, 4096, 8192, 16384, 32768];

pub fn reps_for(n_tokens: usize) -> (usize, usize) {
    // (warmup, reps): paper uses 10/100; scale down as work grows.
    match n_tokens {
        0..=2048 => (10, 100),
        2049..=8192 => (5, 30),
        _ => (3, 10),
    }
}

pub fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_normal()).collect()
}

/// Per-KV-head data for one layer at `n` tokens.
pub struct LayerData {
    pub keys: Vec<Vec<f32>>, // [n_kv] of n*d_h
    pub vals: Vec<Vec<f32>>,
    pub q: Vec<f32>,   // n_q * d_h query block
    pub p: Vec<f32>,   // n softmax weights (shared across heads for the bench)
}

pub fn layer_data(n: usize, seed: u64) -> LayerData {
    let mut rng = Rng::new(seed);
    let keys = (0..N_KV).map(|_| rand_vec(&mut rng, n * D_H)).collect();
    let vals = (0..N_KV).map(|_| rand_vec(&mut rng, n * D_H)).collect();
    let q = rand_vec(&mut rng, N_Q * D_H);
    let mut p = rand_vec(&mut rng, n);
    // NaN-safe max seed (see kernels::softmax): f32::MIN is wrong for
    // all-negative-infinite input and silently propagates NaN.
    let m = p
        .iter()
        .filter(|v| !v.is_nan())
        .fold(f32::NEG_INFINITY, |a, &b| if b.total_cmp(&a).is_gt() { b } else { a });
    assert!(m.is_finite(), "bench softmax max must be finite");
    let mut s = 0.0;
    for v in p.iter_mut() {
        *v = (*v - m).exp();
        s += *v;
    }
    for v in p.iter_mut() {
        *v /= s;
    }
    LayerData { keys, vals, q, p }
}

pub struct BuiltSegments {
    pub inner_k: Vec<InnerKeySegment>,
    pub inner_v3: Vec<InnerValSegment>,
    pub inner_v2: Vec<InnerValSegment>,
    pub inner_v2h: Vec<InnerValSegment>,
    pub outer_k: Vec<OuterKeySegment>,
    pub outer_v: Vec<OuterValSegment>,
    pub turbo_k: Vec<TurboKeySegment>,
    pub turbo_v: Vec<TurboValSegment>,
}

pub fn build_segments(d: &LayerData, n: usize) -> BuiltSegments {
    let mut b = BuiltSegments {
        inner_k: Vec::new(),
        inner_v3: Vec::new(),
        inner_v2: Vec::new(),
        inner_v2h: Vec::new(),
        outer_k: Vec::new(),
        outer_v: Vec::new(),
        turbo_k: Vec::new(),
        turbo_v: Vec::new(),
    };
    for h in 0..N_KV {
        let mut ik = InnerKeySegment::new(D_H, 3, Mode::Sym);
        for row in d.keys[h].chunks_exact(D_H) {
            ik.append_token(row);
        }
        b.inner_k.push(ik);
        let mut iv3 = InnerValSegment::new(D_H, 3, Mode::Sym);
        let mut iv2 = InnerValSegment::new(D_H, 2, Mode::Sym);
        let mut iv2h = InnerValSegment::new(D_H, 2, Mode::Hybrid);
        for chunk in d.vals[h].chunks_exact(32 * D_H) {
            iv3.append_chunk(chunk);
            iv2.append_chunk(chunk);
            iv2h.append_chunk(chunk);
        }
        b.inner_v3.push(iv3);
        b.inner_v2.push(iv2);
        b.inner_v2h.push(iv2h);
        let mut ok = OuterKeySegment::new(D_H, 2, Mode::Asym);
        for chunk in d.keys[h].chunks_exact(32 * D_H) {
            ok.append_chunk(chunk);
        }
        b.outer_k.push(ok);
        let mut ov = OuterValSegment::new(D_H, 2, Mode::Asym);
        for row in d.vals[h].chunks_exact(D_H) {
            ov.append_token(row);
        }
        b.outer_v.push(ov);
        let mut tk = TurboKeySegment::new(D_H, 4, 42);
        let mut tv = TurboValSegment::new(D_H, 3, 43);
        for (krow, vrow) in d.keys[h].chunks_exact(D_H).zip(d.vals[h].chunks_exact(D_H)) {
            tk.append_token(krow);
            tv.append_token(vrow);
        }
        b.turbo_k.push(tk);
        b.turbo_v.push(tv);
    }
    let _ = n;
    b
}
