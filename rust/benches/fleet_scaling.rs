//! Data-parallel fleet scaling: replay the same traces through `replay_fleet`
//! while sweeping replica count x router policy x trace family, and record
//! throughput, completion, migration traffic, and the cache-locality
//! counters (prefix bytes shared, restore bytes) that separate the affinity
//! router from blind placement. The multi-turn family round-robins requests
//! over 5 sessions sharing a long prefix — 5 is coprime with every replica
//! count swept, so session→replica alignment can never make policies agree
//! by accident. A single-turn control family (nothing shareable, no
//! locality to exploit) rides along.
//!
//! Before timing anything the run asserts two contracts (any panic fails
//! CI):
//!   * determinism — per (policy, replicas, trace), the full fleet report
//!     is byte-identical between workers=1 and workers=4 and across
//!     back-to-back runs;
//!   * locality — on the multi-turn trace at every replica count > 1, the
//!     affinity router strictly increases prefix bytes shared AND strictly
//!     reduces priced restore+prefill work (prefill + restore cost minus
//!     the prefix-sharing credit, under the replay `CostModel`) versus
//!     round-robin placement.
//!
//! ```bash
//! cargo bench --bench fleet_scaling           # full sweep
//! cargo bench --bench fleet_scaling quick     # CI smoke
//! ```

use innerq::coordinator::{Engine, Fleet, Policy, Preemption, Scheduler, StepMetrics};
use innerq::quant::MethodConfig;
use innerq::runtime::Manifest;
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::util::json::Json;
use innerq::workload::replay::{replay_fleet, CostModel, FleetReplayReport, Outcome};
use innerq::workload::trace::{
    generate_multi_turn, generate_timed, Arrival, MultiTurnTraceConfig, TimedRequest,
    TimedTraceConfig,
};
use innerq::QuantMethod;

/// Comfortable per-replica budget: the sweep measures placement quality,
/// not admission control, so nothing should be rejected at any replica
/// count on these traces.
const BUDGET: usize = 64_000;
const SEED: u64 = 2026;
/// Coprime with the swept replica counts {1, 2, 4} — see module docs.
const SESSIONS: usize = 5;

/// Paper bit-widths, serving-sized windows: with the default 128-token
/// window the fake model's bucket-sized prompts never quantize their
/// prefix and there would be nothing for the affinity router to score.
fn serving_cfg() -> MethodConfig {
    let mut cfg = QuantMethod::InnerQBase.config();
    cfg.w_sink = cfg.w_sink.min(4);
    cfg.w_recent = cfg.w_recent.min(8).max(4);
    cfg
}

fn replica(dir: &std::path::Path, workers: usize) -> Scheduler {
    let manifest = Manifest::load(dir).expect("fake manifest");
    let mut engine = Engine::new(manifest, serving_cfg()).expect("engine");
    engine.set_workers(workers);
    let mut sched = Scheduler::new(engine, BUDGET);
    sched.set_policy(Policy::Slo);
    sched.set_preemption(Preemption::Offload);
    sched.set_warm_budget(1 << 20);
    sched
}

fn fleet(dir: &std::path::Path, policy: &str, n_replicas: usize, workers: usize) -> Fleet {
    let router = innerq::coordinator::parse_router(policy).expect("router name");
    Fleet::new((0..n_replicas).map(|_| replica(dir, workers)).collect(), router)
}

/// Chat-style family: long shared session prefixes, short per-turn suffixes.
fn multi_turn_trace(rate_rps: f64, n_requests: usize) -> Vec<TimedRequest> {
    generate_multi_turn(&MultiTurnTraceConfig {
        base: TimedTraceConfig {
            n_requests,
            arrival: Arrival::Poisson { rate_rps },
            vars_range: (2, 4),
            seed: SEED,
            ..TimedTraceConfig::default()
        },
        n_sessions: SESSIONS,
        prefix_vars: 20,
    })
}

/// Control family: independent prompts, nothing shareable.
fn single_turn_trace(rate_rps: f64, n_requests: usize) -> Vec<TimedRequest> {
    generate_timed(&TimedTraceConfig {
        n_requests,
        arrival: Arrival::Poisson { rate_rps },
        seed: SEED,
        ..TimedTraceConfig::default()
    })
}

fn run_cell(
    dir: &std::path::Path,
    policy: &str,
    n_replicas: usize,
    workers: usize,
    trace: &[TimedRequest],
    cost: &CostModel,
) -> FleetReplayReport {
    let mut f = fleet(dir, policy, n_replicas, workers);
    replay_fleet(&mut f, trace, cost).expect("fleet replay")
}

/// Virtual microseconds of restore + prefill work the fleet was priced for,
/// net of the prefix-sharing credit — the quantity the affinity router
/// exists to shrink. Restores and prefix savings use the same per-KiB
/// rounding as `CostModel` pricing so the comparison is exact.
fn priced_work_us(m: &StepMetrics, cost: &CostModel) -> i64 {
    let prefill = m.prefill_tokens * cost.prefill_us_per_token;
    let restore = m.restore_bytes * cost.restore_us_per_kib / 1024;
    let saving = m.prefix_bytes_shared * cost.prefix_saving_us_per_kib / 1024;
    prefill as i64 + restore as i64 - saving as i64
}

/// Determinism contract: per (policy, replicas) on the multi-turn trace,
/// the full fleet report — placement, per-replica latencies, everything —
/// is byte-identical between workers=1 and workers=4 and across
/// back-to-back runs.
fn assert_determinism_contract(
    dir: &std::path::Path,
    policies: &[&'static str],
    replica_counts: &[usize],
    trace: &[TimedRequest],
    cost: &CostModel,
) {
    for &policy in policies {
        for &n in replica_counts {
            let a = run_cell(dir, policy, n, 1, trace, cost).to_json().dump();
            let b = run_cell(dir, policy, n, 4, trace, cost).to_json().dump();
            assert_eq!(a, b, "{policy} x{n}: fleet replay diverged between workers=1 and 4");
            let c = run_cell(dir, policy, n, 1, trace, cost).to_json().dump();
            assert_eq!(a, c, "{policy} x{n}: fleet replay diverged across back-to-back runs");
        }
    }
    eprintln!(
        "[fleet_scaling] determinism contract holds ({} policies x {:?} replicas)",
        policies.len(),
        replica_counts
    );
}

struct Cell {
    policy: &'static str,
    replicas: usize,
    trace: &'static str,
    rate_rps: f64,
    report: FleetReplayReport,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "quick");
    let n_requests: usize = args
        .iter()
        .filter_map(|a| a.parse().ok())
        .next()
        .unwrap_or(if quick { 40 } else { 80 });
    let rate = 2000.0; // far past single-replica capacity: placement matters
    let replica_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let policies: &[&'static str] = &["round-robin", "least-loaded", "affinity"];
    let cost = CostModel::default();
    let dir = write_fake_artifacts("fleet_scaling", '7');

    eprintln!(
        "[fleet_scaling] {n_requests} requests/cell, {} policies x {:?} replicas x 2 traces, \
         budget={BUDGET}/replica, quick={quick}",
        policies.len(),
        replica_counts
    );

    let families: [(&'static str, fn(f64, usize) -> Vec<TimedRequest>); 2] =
        [("multi_turn", multi_turn_trace), ("single_turn", single_turn_trace)];

    assert_determinism_contract(
        &dir,
        policies,
        replica_counts,
        &multi_turn_trace(rate, n_requests),
        &cost,
    );

    // Locality contract: at every replica count > 1 the affinity router
    // must strictly beat round-robin on the multi-turn trace, both on raw
    // cache locality (prefix bytes shared) and on the priced work bill —
    // asserted before any cell is recorded.
    let mut cells: Vec<Cell> = Vec::new();
    for (family, gen) in families {
        let trace = gen(rate, n_requests);
        for &n in replica_counts {
            let mut by_policy: Vec<(&'static str, u64, i64)> = Vec::new();
            for &policy in policies {
                let report = run_cell(&dir, policy, n, 1, &trace, &cost);
                assert_eq!(
                    report.completed(),
                    n_requests,
                    "{policy} x{n} {family}: every request must complete at this budget"
                );
                by_policy.push((
                    policy,
                    report.metrics.prefix_bytes_shared,
                    priced_work_us(&report.metrics, &cost),
                ));
                cells.push(Cell { policy, replicas: n, trace: family, rate_rps: rate, report });
            }
            if family == "multi_turn" && n > 1 {
                let shared = |p: &str| by_policy.iter().find(|c| c.0 == p).unwrap().1;
                let work = |p: &str| by_policy.iter().find(|c| c.0 == p).unwrap().2;
                assert!(
                    shared("affinity") > shared("round-robin"),
                    "x{n}: affinity must strictly increase prefix bytes shared \
                     (affinity={} vs round-robin={})",
                    shared("affinity"),
                    shared("round-robin")
                );
                assert!(
                    work("affinity") < work("round-robin"),
                    "x{n}: affinity must strictly reduce priced restore+prefill work \
                     (affinity={}us vs round-robin={}us)",
                    work("affinity"),
                    work("round-robin")
                );
            }
        }
    }
    eprintln!("[fleet_scaling] locality contract holds (affinity beats round-robin, multi-turn)");

    println!(
        "{:<13} {:<12} {:>4} {:>5} {:>5} {:>7} {:>10} {:>10} {:>12} {:>8}",
        "policy", "trace", "reps", "ok", "migr", "hits", "shared_kb", "restore_kb", "work_us",
        "req/s"
    );
    for c in &cells {
        println!(
            "{:<13} {:<12} {:>4} {:>5} {:>5} {:>7} {:>10.1} {:>10.1} {:>12} {:>8.1}",
            c.policy,
            c.trace,
            c.replicas,
            c.report.completed(),
            c.report.migrations,
            c.report.metrics.prefix_hits,
            c.report.metrics.prefix_bytes_shared as f64 / 1024.0,
            c.report.metrics.restore_bytes as f64 / 1024.0,
            priced_work_us(&c.report.metrics, &cost),
            c.report.throughput_rps(),
        );
    }

    let results: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("policy", Json::str(c.policy)),
                ("replicas", Json::Num(c.replicas as f64)),
                ("trace", Json::str(c.trace)),
                ("rate_rps", Json::Num(c.rate_rps)),
                ("budget_bytes", Json::Num(BUDGET as f64)),
                ("n_requests", Json::Num(c.report.n_requests() as f64)),
                ("completed", Json::Num(c.report.completed() as f64)),
                (
                    "rejected",
                    Json::Num(
                        c.report.replicas.iter().map(|r| r.count(Outcome::Rejected)).sum::<usize>()
                            as f64,
                    ),
                ),
                ("migrations", Json::Num(c.report.migrations as f64)),
                ("migrated_bytes", Json::Num(c.report.migrated_bytes as f64)),
                ("prefix_hits", Json::Num(c.report.metrics.prefix_hits as f64)),
                (
                    "prefix_bytes_shared",
                    Json::Num(c.report.metrics.prefix_bytes_shared as f64),
                ),
                ("restores", Json::Num(c.report.metrics.restores as f64)),
                ("restore_bytes", Json::Num(c.report.metrics.restore_bytes as f64)),
                ("prefill_tokens", Json::Num(c.report.metrics.prefill_tokens as f64)),
                (
                    "priced_work_us",
                    Json::Num(priced_work_us(&c.report.metrics, &cost) as f64),
                ),
                ("ticks", Json::Num(c.report.ticks() as f64)),
                ("virtual_us", Json::Num(c.report.end_us() as f64)),
                ("throughput_rps", Json::Num(c.report.throughput_rps())),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("fleet_scaling")),
        ("quick", Json::Bool(quick)),
        ("n_requests", Json::Num(n_requests as f64)),
        ("policy", Json::str("slo")),
        ("budget_bytes", Json::Num(BUDGET as f64)),
        ("results", Json::Arr(results)),
    ]);
    let path = "BENCH_fleet.json";
    std::fs::write(path, doc.dump()).expect("write BENCH_fleet.json");
    eprintln!("[fleet_scaling] wrote {path}");
}
