//! Decode scaling with a pipeline axis: a multi-layer synthetic decode step
//! (append + attend per (layer, sequence, KV head)) swept over
//! `pipeline {barrier, overlap}` × worker count × batch size at one
//! Llama-3.1-8B layer geometry (32 q heads over 8 KV heads, d_h 128,
//! InnerQ_Base caches, 4 layers).
//!
//! * `barrier` reproduces the engine's old per-layer phase barriers: every
//!   head's K/V append runs serially on the driver, then the layer's
//!   attention fans out behind a full pool barrier — layer after layer.
//! * `overlap` emits the whole step as one `ThreadPool::run_graph` of fused
//!   append+attend jobs (`cache::step_fanout`, the engine's pipelined job
//!   shape). The bench's per-layer inputs are precomputed, so unlike the
//!   engine (where qkv(l+1) depends on out(l)) the layers here may overlap
//!   outright — this is the upper bound on what killing the barrier buys.
//!
//! The harness *checks* the determinism contract before timing: every
//! (mode, workers) combination must reproduce the barrier/workers=1 context
//! buffers byte-for-byte and leave bit-identical caches. It then emits a
//! machine-readable `BENCH_decode.json` (step µs + attention tokens/s per
//! cell) for the cross-PR trajectory check.
//!
//! ```bash
//! cargo bench --bench decode_scaling              # full sweep (1024 tok)
//! cargo bench --bench decode_scaling 256          # override tokens/seq
//! cargo bench --bench decode_scaling quick        # fewer timing reps
//! ```

use innerq::cache::{attention_fanout, step_fanout, HeadCache, LayerCache};
use innerq::util::json::Json;
use innerq::util::rng::Rng;
use innerq::util::stats::time_us;
use innerq::util::threadpool::{Stage, ThreadPool};
use innerq::QuantMethod;

const D_H: usize = 128;
const N_KV: usize = 8;
const N_Q: usize = 32;
const REP: usize = N_Q / N_KV;
const N_LAYERS: usize = 4;

/// Per-sequence caches, `[seq][layer]`, built deterministically from `seed`
/// so every (mode, workers) cell starts from bit-identical state.
fn build_caches(batch: usize, n_tokens: usize, seed: u64) -> Vec<Vec<LayerCache>> {
    let cfg = QuantMethod::InnerQBase.config();
    let mut rng = Rng::new(seed);
    (0..batch)
        .map(|_| {
            (0..N_LAYERS)
                .map(|_| {
                    LayerCache::from_heads(
                        (0..N_KV)
                            .map(|_| {
                                let keys: Vec<f32> =
                                    (0..n_tokens * D_H).map(|_| rng.next_normal()).collect();
                                let vals: Vec<f32> =
                                    (0..n_tokens * D_H).map(|_| rng.next_normal()).collect();
                                HeadCache::from_prefill(cfg, D_H, &keys, &vals)
                            })
                            .collect(),
                    )
                })
                .collect()
        })
        .collect()
}

/// One decode step, old shape: per layer, serial driver appends then a
/// barriered attention fan-out (the shared `attention_fanout` job shape).
fn barrier_step(
    pool: &ThreadPool,
    caches: &mut [Vec<LayerCache>],
    k: &[Vec<f32>],
    v: &[Vec<f32>],
    q: &[f32],
    ctxs: &mut [Vec<f32>],
) {
    for l in 0..N_LAYERS {
        for (i, s) in caches.iter_mut().enumerate() {
            for (hk, head) in s[l].heads_mut().iter_mut().enumerate() {
                let kb = (i * N_KV + hk) * D_H;
                head.append(&k[l][kb..kb + D_H], &v[l][kb..kb + D_H]);
            }
        }
        let heads = caches.iter().flat_map(|s| s[l].heads().iter());
        pool.run(attention_fanout(heads, q, &mut ctxs[l], REP, D_H));
    }
}

/// One decode step, pipelined shape: the whole multi-layer step as one
/// graph of fused append+attend jobs — no barrier anywhere, layers overlap.
fn overlap_step(
    pool: &ThreadPool,
    caches: &mut [Vec<LayerCache>],
    k: &[Vec<f32>],
    v: &[Vec<f32>],
    q: &[f32],
    ctxs: &mut [Vec<f32>],
) {
    let mut layer_heads: Vec<Vec<&mut HeadCache>> = (0..N_LAYERS).map(|_| Vec::new()).collect();
    for s in caches.iter_mut() {
        for (l, lc) in s.iter_mut().enumerate() {
            layer_heads[l].extend(lc.heads_mut().iter_mut());
        }
    }
    let mut stages: Vec<Stage> = Vec::with_capacity(N_LAYERS);
    for ((heads, ctx), (kl, vl)) in layer_heads
        .into_iter()
        .zip(ctxs.iter_mut())
        .zip(k.iter().zip(v.iter()))
    {
        stages.push(Stage::new(Vec::new(), step_fanout(heads, kl, vl, q, ctx, REP, D_H)));
    }
    pool.run_graph(stages);
}

fn run_step(
    mode: &str,
    pool: &ThreadPool,
    caches: &mut [Vec<LayerCache>],
    k: &[Vec<f32>],
    v: &[Vec<f32>],
    q: &[f32],
    ctxs: &mut [Vec<f32>],
) {
    match mode {
        "barrier" => barrier_step(pool, caches, k, v, q, ctxs),
        _ => overlap_step(pool, caches, k, v, q, ctxs),
    }
}

struct Record {
    pipeline: &'static str,
    batch: usize,
    workers: usize,
    step_us: f64,
    tokens_per_s: f64,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "quick");
    let n_tokens: usize = args.iter().filter_map(|a| a.parse().ok()).next().unwrap_or(1024);
    let batches = [1usize, 2, 4, 8];
    let worker_counts = [1usize, 2, 4, 8];
    let modes = ["barrier", "overlap"];
    let max_batch = *batches.last().unwrap();

    eprintln!(
        "[decode_scaling] {max_batch} seqs x {N_LAYERS} layers x {N_KV} InnerQ caches @ {n_tokens} tokens"
    );

    // Per-step inputs, fixed across the whole sweep.
    let mut rng = Rng::new(2026);
    let k_rows: Vec<Vec<f32>> = (0..N_LAYERS)
        .map(|_| (0..max_batch * N_KV * D_H).map(|_| rng.next_normal()).collect())
        .collect();
    let v_rows: Vec<Vec<f32>> = (0..N_LAYERS)
        .map(|_| (0..max_batch * N_KV * D_H).map(|_| rng.next_normal()).collect())
        .collect();
    let q: Vec<f32> = (0..max_batch * N_Q * D_H).map(|_| rng.next_normal()).collect();

    // ---- determinism contract: every (mode, workers) cell must match the
    // barrier/workers=1 reference byte-for-byte, contexts and caches ----
    {
        let det_tokens = n_tokens.min(256); // keep the check cheap
        let det_batch = 2usize;
        let steps = 6; // crosses an InnerQ value-eviction boundary cadence
        let qd = &q[..det_batch * N_Q * D_H];
        let reference = {
            let pool = ThreadPool::new(1);
            let mut caches = build_caches(det_batch, det_tokens, 7);
            let mut ctxs: Vec<Vec<f32>> =
                (0..N_LAYERS).map(|_| vec![0f32; det_batch * N_Q * D_H]).collect();
            let mut all_ctx = Vec::new();
            for _ in 0..steps {
                barrier_step(&pool, &mut caches, &k_rows, &v_rows, qd, &mut ctxs);
                all_ctx.push(ctxs.clone());
            }
            (caches, all_ctx)
        };
        for mode in modes {
            for &workers in &worker_counts {
                let pool = ThreadPool::new(workers);
                let mut caches = build_caches(det_batch, det_tokens, 7);
                let mut ctxs: Vec<Vec<f32>> =
                    (0..N_LAYERS).map(|_| vec![0f32; det_batch * N_Q * D_H]).collect();
                for step in 0..steps {
                    run_step(mode, &pool, &mut caches, &k_rows, &v_rows, qd, &mut ctxs);
                    assert_eq!(
                        ctxs, reference.1[step],
                        "{mode} workers={workers} step {step}: ctx diverged from barrier/1"
                    );
                }
                assert_eq!(
                    caches, reference.0,
                    "{mode} workers={workers}: cache state diverged from barrier/1"
                );
            }
        }
        eprintln!("[decode_scaling] determinism contract holds (barrier == overlap, all worker counts)");
    }

    // ---- timing sweep ----
    println!(
        "Decode step scaling (InnerQ_Base, {N_LAYERS} layers, d_h {D_H}, {N_KV} KV heads x{REP} GQA, {n_tokens} tok/seq)"
    );
    println!(
        "{:<9} {:<7} {:>9} {:>12} {:>12} {:>10}",
        "pipeline", "batch", "workers", "step µs", "speedup", "tok/s"
    );

    let mut records: Vec<Record> = Vec::new();
    for &batch in &batches {
        let q = &q[..batch * N_Q * D_H];
        let mut base_us = 0.0f64;
        for mode in modes {
            for &workers in &worker_counts {
                let pool = ThreadPool::new(workers);
                // Fresh caches per cell so growth from timed appends cannot
                // leak across cells; every cell grows identically.
                let mut caches = build_caches(batch, n_tokens, 11);
                let mut ctxs: Vec<Vec<f32>> =
                    (0..N_LAYERS).map(|_| vec![0f32; batch * N_Q * D_H]).collect();
                let (w, r) = if quick {
                    (1, 3)
                } else if n_tokens <= 2048 {
                    (3, 12)
                } else {
                    (2, 6)
                };
                let s = time_us(w, r, || {
                    run_step(mode, &pool, &mut caches, &k_rows, &v_rows, q, &mut ctxs);
                    ctxs[0][0]
                });
                if mode == "barrier" && workers == 1 {
                    base_us = s.mean_us;
                }
                // Attention "token throughput": cache tokens scored+mixed
                // per second across all query heads, layers, and sequences.
                let toks = (batch * N_Q * n_tokens * N_LAYERS) as f64 / (s.mean_us * 1e-6);
                println!(
                    "{:<9} {:<7} {:>9} {:>12.0} {:>11.2}x {:>10.2e}",
                    mode,
                    batch,
                    workers,
                    s.mean_us,
                    base_us / s.mean_us,
                    toks
                );
                records.push(Record {
                    pipeline: if mode == "barrier" { "barrier" } else { "overlap" },
                    batch,
                    workers,
                    step_us: s.mean_us,
                    tokens_per_s: toks,
                });
            }
        }
        if batch == 8 {
            println!(
                "(acceptance: expect overlap >= barrier throughput at workers >= 2 on >= 4 cores)"
            );
        }
    }

    // Machine-readable trajectory record.
    let results: Vec<Json> = records
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("pipeline", Json::str(r.pipeline)),
                ("batch", Json::Num(r.batch as f64)),
                ("workers", Json::Num(r.workers as f64)),
                ("n_layers", Json::Num(N_LAYERS as f64)),
                ("n_tokens", Json::Num(n_tokens as f64)),
                ("d_h", Json::Num(D_H as f64)),
                ("step_us", Json::Num(r.step_us)),
                ("tokens_per_s", Json::Num(r.tokens_per_s)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("decode_scaling")),
        ("quick", Json::Bool(quick)),
        ("n_tokens", Json::Num(n_tokens as f64)),
        ("n_layers", Json::Num(N_LAYERS as f64)),
        ("results", Json::Arr(results)),
    ]);
    let path = "BENCH_decode.json";
    std::fs::write(path, doc.dump()).expect("write BENCH_decode.json");
    eprintln!("[decode_scaling] wrote {path}");
}
