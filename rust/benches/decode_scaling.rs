//! Decode attention scaling: batched (sequence x KV head) fan-out through
//! the worker pool, sweeping batch size x worker count at one Llama-3.1-8B
//! layer geometry (32 q heads over 8 KV heads, d_h 128, InnerQ_Base caches).
//!
//! This is the tentpole measurement for the parallel decode path: jobs are
//! built exactly like `Engine::decode_step` builds them (one job per
//! sequence x KV head, owning a contiguous rep*d_h slice of the context
//! buffer), so the numbers are the engine's attention phase without PJRT
//! stage noise. The harness also *checks* the determinism contract: every
//! worker count must reproduce the workers=1 context buffer byte-for-byte.
//!
//! ```bash
//! cargo bench --bench decode_scaling              # full sweep
//! cargo bench --bench decode_scaling 1024         # override tokens/seq
//! ```

use innerq::cache::{attention_fanout, HeadCache};
use innerq::util::rng::Rng;
use innerq::util::stats::time_us;
use innerq::util::threadpool::ThreadPool;
use innerq::QuantMethod;

const D_H: usize = 128;
const N_KV: usize = 8;
const N_Q: usize = 32;
const REP: usize = N_Q / N_KV;

/// One decode step's attention fan-out over `caches[..batch]`, built by the
/// same `attention_fanout` the engine uses so the bench cannot drift from
/// the production job shape.
fn step(pool: &ThreadPool, caches: &[Vec<HeadCache>], q: &[f32], ctx: &mut [f32]) {
    let heads = caches.iter().flat_map(|s| s.iter());
    pool.run(attention_fanout(heads, q, ctx, REP, D_H));
}

fn main() {
    let n_tokens: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(1024);
    let batches = [1usize, 2, 4, 8];
    let worker_counts = [1usize, 2, 4, 8];
    let max_batch = *batches.last().unwrap();

    eprintln!(
        "[decode_scaling] building {max_batch} x {N_KV} InnerQ caches @ {n_tokens} tokens ..."
    );
    let cfg = QuantMethod::InnerQBase.config();
    let mut rng = Rng::new(2026);
    let caches: Vec<Vec<HeadCache>> = (0..max_batch)
        .map(|_| {
            (0..N_KV)
                .map(|_| {
                    let keys: Vec<f32> =
                        (0..n_tokens * D_H).map(|_| rng.next_normal()).collect();
                    let vals: Vec<f32> =
                        (0..n_tokens * D_H).map(|_| rng.next_normal()).collect();
                    HeadCache::from_prefill(cfg, D_H, &keys, &vals)
                })
                .collect()
        })
        .collect();
    let q: Vec<f32> = (0..max_batch * N_Q * D_H).map(|_| rng.next_normal()).collect();

    println!(
        "Decode attention scaling (InnerQ_Base, d_h {D_H}, {N_KV} KV heads x{REP} GQA, {n_tokens} tok/seq)"
    );
    println!(
        "{:<7} {:>9} {:>12} {:>12} {:>10} {:>12}",
        "batch", "workers", "step µs", "speedup", "tok/s", "identical"
    );

    for &batch in &batches {
        let caches = &caches[..batch];
        let q = &q[..batch * N_Q * D_H];
        let mut serial_ctx: Option<Vec<f32>> = None;
        let mut serial_us = 0.0f64;
        for &workers in &worker_counts {
            let pool = ThreadPool::new(workers);
            let mut ctx = vec![0f32; batch * N_Q * D_H];
            let (w, r) = if n_tokens <= 2048 { (3, 12) } else { (2, 6) };
            let s = time_us(w, r, || {
                step(&pool, caches, q, &mut ctx);
                ctx[0]
            });
            // Determinism contract: byte-identical to the serial baseline.
            let identical = match &serial_ctx {
                None => {
                    serial_ctx = Some(ctx.clone());
                    serial_us = s.mean_us;
                    true
                }
                Some(base) => base == &ctx,
            };
            assert!(
                identical,
                "batch {batch} workers {workers}: context diverged from serial"
            );
            // Attention "token throughput": cache tokens scored+mixed per
            // second across all query heads of the batch.
            let toks = (batch * N_Q * n_tokens) as f64 / (s.mean_us * 1e-6);
            println!(
                "{:<7} {:>9} {:>12.0} {:>11.2}x {:>10.2e} {:>12}",
                batch,
                workers,
                s.mean_us,
                serial_us / s.mean_us,
                toks,
                identical
            );
        }
        if batch == 8 {
            println!("(acceptance: expect >= 2x speedup at batch 8, workers 4, on >= 4 cores)");
        }
    }
}
