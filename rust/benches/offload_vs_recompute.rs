//! Offload vs recompute preemption under the overload sweep: replay the
//! same overloaded SLO trace through the scheduler in both preemption
//! modes, per quantization method, and record throughput, tail latency, and
//! the offload/restore traffic — the harness that answers the ROADMAP
//! question "does quantized-cache offload-to-host beat recompute under the
//! cost model?". Smaller snapshots (harder compression) make restores
//! cheaper while recompute always pays the full prefill again, so the
//! per-method split is the interesting axis.
//!
//! Before timing anything the run asserts two contracts (any panic or
//! mismatch fails CI):
//!   * snapshot bit-identity — every quantized segment variant round-trips
//!     through `cache::store::snapshot` to an equal cache and identical
//!     bytes;
//!   * replay byte-identity — the offload-mode replay report is identical
//!     between workers=1 and workers=2.
//!
//! ```bash
//! cargo bench --bench offload_vs_recompute           # full sweep
//! cargo bench --bench offload_vs_recompute quick     # CI smoke
//! ```

use innerq::cache::store::{restore_head, snapshot_head};
use innerq::cache::HeadCache;
use innerq::coordinator::{Engine, Policy, Preemption, Scheduler};
use innerq::runtime::Manifest;
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::util::json::Json;
use innerq::util::ptest::normal_vec;
use innerq::util::rng::Rng;
use innerq::workload::replay::{replay, CostModel, Outcome, ReplayReport};
use innerq::workload::trace::{generate_timed, Arrival, TimedRequest, TimedTraceConfig};
use innerq::QuantMethod;

/// Tight budget (≈ 2 concurrent sequences at the fake geometry) so the
/// overloaded trace actually preempts.
const BUDGET: usize = 64_000;
const WARM_BUDGET: usize = 1 << 20;

fn scheduler(
    dir: &std::path::Path,
    method: QuantMethod,
    mode: Preemption,
    workers: usize,
) -> Scheduler {
    let manifest = Manifest::load(dir).expect("fake manifest");
    let mut engine = Engine::new(manifest, method.config()).expect("engine");
    engine.set_workers(workers);
    let mut sched = Scheduler::new(engine, BUDGET);
    sched.set_policy(Policy::Slo);
    sched.set_preemption(mode);
    sched.set_warm_budget(WARM_BUDGET);
    sched
}

fn trace_for(rate_rps: f64, n_requests: usize) -> Vec<TimedRequest> {
    generate_timed(&TimedTraceConfig {
        n_requests,
        arrival: Arrival::Poisson { rate_rps },
        // All three classes so SLO preemption (strictly-lower-class victims)
        // actually fires; no deadlines, so preempted work must finish and
        // the restore-vs-reprefill cost shows up in e2e latency.
        priority_mix: [1.0, 2.0, 1.0],
        seed: 2026,
        ..TimedTraceConfig::default()
    })
}

/// Snapshot bit-identity smoke over every quantized segment layout the
/// sweep's methods use (plus turbo): quantize ragged-length caches, round
/// trip, and require equality and byte-identical re-serialization.
fn assert_snapshot_contract() {
    let d_h = 64;
    let mut seed = 0xbe9c_0001u64;
    for m in QuantMethod::ALL {
        for n in [100usize, 131, 240] {
            seed += 1;
            let mut rng = Rng::new(seed);
            let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
            let vals = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
            let hc = HeadCache::from_prefill(m.config(), d_h, &keys, &vals);
            let bytes = snapshot_head(&hc);
            let back = restore_head(&bytes).expect("restore");
            assert_eq!(back, hc, "{m:?} n={n}: snapshot round trip diverged");
            assert_eq!(snapshot_head(&back), bytes, "{m:?} n={n}: bytes diverged");
        }
    }
    eprintln!("[offload_vs_recompute] snapshot bit-identity contract holds");
}

struct Cell {
    rate_rps: f64,
    method: QuantMethod,
    mode: Preemption,
    report: ReplayReport,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "quick");
    let n_requests: usize = args
        .iter()
        .filter_map(|a| a.parse().ok())
        .next()
        .unwrap_or(if quick { 32 } else { 96 });
    let rates: &[f64] = if quick { &[900.0] } else { &[300.0, 900.0, 2000.0] };
    let methods: &[QuantMethod] = if quick {
        &[QuantMethod::InnerQBase, QuantMethod::BaselineFp16]
    } else {
        &[QuantMethod::InnerQBase, QuantMethod::Kivi, QuantMethod::BaselineFp16]
    };
    let modes = [Preemption::Recompute, Preemption::Offload];
    let cost = CostModel::default();
    let dir = write_fake_artifacts("offload_vs_recompute", '7');

    eprintln!(
        "[offload_vs_recompute] {n_requests} requests/cell, {} rates x {} methods x 2 modes, \
         budget={BUDGET}, quick={quick}",
        rates.len(),
        methods.len()
    );

    assert_snapshot_contract();

    // Replay byte-identity with offloads in the event stream.
    {
        let trace = trace_for(rates[0], n_requests);
        let mut s1 = scheduler(&dir, QuantMethod::InnerQBase, Preemption::Offload, 1);
        let mut s2 = scheduler(&dir, QuantMethod::InnerQBase, Preemption::Offload, 2);
        let a = replay(&mut s1, &trace, &cost).expect("replay w1");
        let b = replay(&mut s2, &trace, &cost).expect("replay w2");
        assert_eq!(
            a.to_json().dump(),
            b.to_json().dump(),
            "offload replay byte-identity violated between workers=1 and workers=2"
        );
        eprintln!(
            "[offload_vs_recompute] determinism contract holds (workers 1 vs 2, \
             {} offloads / {} restores in stream)",
            a.metrics.offloads, a.metrics.restores
        );
    }

    println!(
        "{:<14} {:>10} {:>6} {:>5} {:>6} {:>5} {:>5} {:>8} {:>10} {:>10}",
        "method", "preemption", "rate", "ok", "preem", "offl", "rest", "req/s", "e2e p50",
        "e2e p99"
    );
    let mut cells: Vec<Cell> = Vec::new();
    let mut any_offloads = 0u64;
    for &rate in rates {
        let trace = trace_for(rate, n_requests);
        for &method in methods {
            for &mode in &modes {
                let mut sched = scheduler(&dir, method, mode, 1);
                let report = replay(&mut sched, &trace, &cost).expect("replay");
                let e = report.overall().e2e.summary();
                if mode == Preemption::Offload {
                    any_offloads += report.metrics.offloads;
                }
                println!(
                    "{:<14} {:>10} {:>6.0} {:>5} {:>6} {:>5} {:>5} {:>8.1} {:>9}µ {:>9}µ",
                    method.name(),
                    mode.name(),
                    rate,
                    report.count(Outcome::Ok),
                    report.metrics.preemptions,
                    report.metrics.offloads,
                    report.metrics.restores,
                    report.throughput_rps(),
                    e.p50_us,
                    e.p99_us,
                );
                cells.push(Cell { rate_rps: rate, method, mode, report });
            }
        }
    }
    assert!(
        any_offloads > 0,
        "the sweep never exercised offload preemption — raise the rates or shrink the budget"
    );

    let results: Vec<Json> = cells
        .iter()
        .map(|c| {
            let o = c.report.overall();
            let (t, e) = (o.ttft.summary(), o.e2e.summary());
            Json::obj(vec![
                ("method", Json::str(c.method.name())),
                ("preemption", Json::str(c.mode.name())),
                ("rate_rps", Json::Num(c.rate_rps)),
                ("budget_bytes", Json::Num(BUDGET as f64)),
                ("n_requests", Json::Num(c.report.records.len() as f64)),
                ("completed", Json::Num(c.report.count(Outcome::Ok) as f64)),
                ("rejected", Json::Num(c.report.count(Outcome::Rejected) as f64)),
                ("expired", Json::Num(c.report.count(Outcome::Expired) as f64)),
                ("preemptions", Json::Num(c.report.metrics.preemptions as f64)),
                ("offloads", Json::Num(c.report.metrics.offloads as f64)),
                ("offload_bytes", Json::Num(c.report.metrics.offload_bytes as f64)),
                ("restores", Json::Num(c.report.metrics.restores as f64)),
                ("offload_lost", Json::Num(c.report.metrics.offload_lost as f64)),
                ("throughput_rps", Json::Num(c.report.throughput_rps())),
                ("gen_tokens_per_s", Json::Num(c.report.gen_tokens_per_s())),
                ("ttft_p50_us", Json::Num(t.p50_us as f64)),
                ("ttft_p99_us", Json::Num(t.p99_us as f64)),
                ("e2e_p50_us", Json::Num(e.p50_us as f64)),
                ("e2e_p99_us", Json::Num(e.p99_us as f64)),
                ("virtual_us", Json::Num(c.report.end_us as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("offload_vs_recompute")),
        ("quick", Json::Bool(quick)),
        ("n_requests", Json::Num(n_requests as f64)),
        ("policy", Json::str("slo")),
        ("budget_bytes", Json::Num(BUDGET as f64)),
        ("warm_budget_bytes", Json::Num(WARM_BUDGET as f64)),
        ("results", Json::Arr(results)),
    ]);
    let path = "BENCH_offload.json";
    std::fs::write(path, doc.dump()).expect("write BENCH_offload.json");
    eprintln!("[offload_vs_recompute] wrote {path}");
}
