//! Figure 4: total speedup of each InnerQ variant over (left) the FP16
//! baseline, (middle) KIVI, and (right) TurboQuant, across sequence lengths.
//! Derived from the same measurements as Table 4 (key op + value op totals).
//!
//! ```bash
//! cargo bench --bench fig4_speedup
//! ```

mod common;

use common::*;
use innerq::kernels::gemv_fp;
use innerq::util::stats::time_us;

struct Totals {
    fp16: f64,
    kivi: f64,
    turbo: f64,
    base: f64,
    hybrid: f64,
    small: f64,
}

fn measure(n: usize) -> Totals {
    let d = layer_data(n, 17);
    let segs = build_segments(&d, n);
    let mut scratch = vec![0f32; D_H];
    let mut scores = vec![0f32; n];
    let mut ctx = vec![0f32; D_H];
    let (w, r) = reps_for(n);
    let rep = N_Q / N_KV;

    let key_fp = time_us(w, r, || {
        for hq in 0..N_Q {
            gemv_fp::qk_fp(&d.q[hq * D_H..(hq + 1) * D_H], &d.keys[hq / rep], D_H, &mut scores);
        }
        scores[0]
    })
    .mean_us;
    let key_kivi = time_us(w, r, || {
        for hq in 0..N_Q {
            segs.outer_k[hq / rep].scores(&d.q[hq * D_H..(hq + 1) * D_H], &mut scratch, &mut scores);
        }
        scores[0]
    })
    .mean_us;
    let key_turbo = time_us(w, r, || {
        for hq in 0..N_Q {
            segs.turbo_k[hq / rep].scores(&d.q[hq * D_H..(hq + 1) * D_H], &mut scores);
        }
        scores[0]
    })
    .mean_us;
    let key_inner = time_us(w, r, || {
        for hq in 0..N_Q {
            segs.inner_k[hq / rep].scores(&d.q[hq * D_H..(hq + 1) * D_H], &mut scores);
        }
        scores[0]
    })
    .mean_us;

    let mut val = |run: &mut dyn FnMut(usize, &mut Vec<f32>)| {
        time_us(w, r, || {
            for hk in 0..N_KV {
                for _ in 0..rep {
                    ctx.iter_mut().for_each(|v| *v = 0.0);
                    run(hk, &mut ctx);
                }
            }
            ctx[0]
        })
        .mean_us
    };
    let mut ctx2 = vec![0f32; D_H];
    let val_fp = {
        let mut f = |hk: usize, c: &mut Vec<f32>| gemv_fp::pv_fp(&d.p, &d.vals[hk], D_H, c);
        val(&mut f)
    };
    let val_kivi = {
        let mut f = |hk: usize, c: &mut Vec<f32>| segs.outer_v[hk].accumulate(&d.p, c);
        val(&mut f)
    };
    let val_turbo = {
        let mut f = |hk: usize, c: &mut Vec<f32>| {
            ctx2.iter_mut().for_each(|v| *v = 0.0);
            segs.turbo_v[hk].accumulate_rotated(&d.p, &mut ctx2);
            segs.turbo_v[hk].finalize_into(ctx2.clone(), c);
        };
        val(&mut f)
    };
    let val_base = {
        let mut f = |hk: usize, c: &mut Vec<f32>| segs.inner_v3[hk].accumulate(&d.p, c);
        val(&mut f)
    };
    let val_hybrid = {
        let mut f = |hk: usize, c: &mut Vec<f32>| segs.inner_v2h[hk].accumulate(&d.p, c);
        val(&mut f)
    };
    let val_small = {
        let mut f = |hk: usize, c: &mut Vec<f32>| segs.inner_v2[hk].accumulate(&d.p, c);
        val(&mut f)
    };

    Totals {
        fp16: key_fp + val_fp,
        kivi: key_kivi + val_kivi,
        turbo: key_turbo + val_turbo,
        base: key_inner + val_base,
        hybrid: key_inner + val_hybrid,
        small: key_inner + val_small,
    }
}

fn main() {
    let lengths = [512usize, 1024, 2048, 4096, 8192, 16384, 32768];
    let mut rows = Vec::new();
    for &n in &lengths {
        rows.push(measure(n));
        eprintln!("  [n={n}] done");
    }

    println!("Figure 4 (measured, CPU): total speedup of InnerQ variants");
    for (title, denom) in [
        ("vs FP16 baseline", 0usize),
        ("vs KIVI", 1),
        ("vs TurboQuant", 2),
    ] {
        println!("\n{title}:");
        println!(
            "{:<16} {}",
            "variant",
            lengths.iter().map(|n| format!("{n:>8}")).collect::<String>()
        );
        for (name, pick) in [
            ("innerq_base", 0usize),
            ("innerq_hybrid", 1),
            ("innerq_small", 2),
        ] {
            print!("{name:<16}");
            for row in &rows {
                let d = match denom {
                    0 => row.fp16,
                    1 => row.kivi,
                    _ => row.turbo,
                };
                let v = match pick {
                    0 => row.base,
                    1 => row.hybrid,
                    _ => row.small,
                };
                print!("{:>8.2}", d / v);
            }
            println!();
        }
    }
    println!("\n(paper Fig. 4: ~2.7x vs FP16, ~1.2-1.4x vs KIVI, ~1.2-1.3x vs TurboQuant, rising with length)");
}
