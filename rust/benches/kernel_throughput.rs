//! Kernel-level throughput for the fused dequant-GEMV hot path: every
//! dispatch arm the host supports (scalar plus AVX2/AVX-512/NEON where
//! detected) vs (a) the pre-PR production shape (`*_prev`: row-at-a-time,
//! u8 fast unpack, AoS params — the honest baseline for the blocking/planar
//! win) and (b) the retained generic scalar references (`*_ref`: the
//! bit-exactness oracle), per bit-width, at the Table-4 head geometry
//! (d_h = 128).
//!
//! Every run *asserts* the cross-arm bit-identity contract before timing —
//! each supported ISA arm and the dispatched entry point must match the
//! scalar reference exactly (CI runs this in quick mode as a smoke test:
//! any panic or bit mismatch fails the build) — then emits both a
//! human-readable table and a machine-readable `BENCH_kernels.json`
//! (tokens/s and ns/row per kernel variant *and ISA arm*) so the perf
//! trajectory is tracked across PRs, plus a SIMD-vs-scalar speedup summary.
//!
//! ```bash
//! cargo bench --bench kernel_throughput          # full run (4096 tokens)
//! cargo bench --bench kernel_throughput quick    # CI smoke (512 tokens)
//! cargo bench --bench kernel_throughput 16384    # override tokens
//! ```

use innerq::cache::segments::{InnerKeySegment, InnerValSegment, OuterKeySegment};
use innerq::kernels::dispatch::{self, Isa};
use innerq::kernels::gemv_inner::{
    pv_inner_chunk, pv_inner_chunk_ref, pv_inner_chunk_with_isa, qk_inner, qk_inner_ref,
    qk_inner_with_isa,
};
use innerq::kernels::gemv_outer::{qk_outer_chunk, qk_outer_chunk_ref, qk_outer_chunk_with_isa};
use innerq::kernels::gemv_fp;
use innerq::quant::group::Mode;
use innerq::quant::packing::{packed_len, unpack32};
use innerq::util::json::Json;
use innerq::util::rng::Rng;
use innerq::util::stats::time_us;

const D_H: usize = 128;

// ---------------------------------------------------------------------------
// Pre-PR production shape, kept verbatim so BENCH_kernels.json tracks the
// *real* improvement of the blocked kernels over what previously shipped —
// not over the deliberately-generic scalar references (which pay a per-code
// bit loop the old hot path never paid). Row-at-a-time, u8 fast unpack,
// interleaved AoS (scale, zeff) pairs.
// ---------------------------------------------------------------------------

fn hsum16(a: &[f32; 16]) -> f32 {
    let mut s8 = [0f32; 8];
    for i in 0..8 {
        s8[i] = a[i] + a[i + 8];
    }
    let s4 = [s8[0] + s8[4], s8[1] + s8[5], s8[2] + s8[6], s8[3] + s8[7]];
    (s4[0] + s4[2]) + (s4[1] + s4[3])
}

fn qk_inner_prev(q: &[f32], codes: &[u8], params: &[(f32, f32)], bits: u8, d_h: usize, out: &mut [f32]) {
    let groups = d_h / 32;
    let gbytes = packed_len(32, bits);
    let row_bytes = groups * gbytes;
    let mut qsum = vec![0f32; groups];
    for (g, s) in qsum.iter_mut().enumerate() {
        *s = q[g * 32..(g + 1) * 32].iter().sum();
    }
    let mut buf = [0u8; 32];
    for (j, o) in out.iter_mut().enumerate() {
        let row = &codes[j * row_bytes..(j + 1) * row_bytes];
        let prow = &params[j * groups..(j + 1) * groups];
        let mut row_acc = [0f32; 16];
        let mut zterm = 0.0f32;
        for g in 0..groups {
            unpack32(&row[g * gbytes..], bits, &mut buf);
            let qg = &q[g * 32..(g + 1) * 32];
            let mut acc = [0f32; 16];
            for half in 0..2 {
                let (qh, bh) = (&qg[half * 16..(half + 1) * 16], &buf[half * 16..(half + 1) * 16]);
                for i in 0..16 {
                    acc[i] += qh[i] * bh[i] as f32;
                }
            }
            let (s, z) = prow[g];
            for i in 0..16 {
                row_acc[i] += s * acc[i];
            }
            zterm += z * qsum[g];
        }
        *o = hsum16(&row_acc) + zterm;
    }
}

fn pv_inner_chunk_prev(
    p: &[f32],
    chunk_codes: &[u8],
    params: &[(f32, f32)],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let gbytes = packed_len(32, bits);
    let row_bytes = (d_h / 32) * gbytes;
    let psum: f32 = p.iter().sum();
    let mut acc = vec![0f32; d_h];
    let mut buf = [0u8; 32];
    for (t, &w) in p.iter().enumerate() {
        let row = &chunk_codes[t * row_bytes..(t + 1) * row_bytes];
        for g in 0..d_h / 32 {
            unpack32(&row[g * gbytes..], bits, &mut buf);
            let ag = &mut acc[g * 32..(g + 1) * 32];
            for i in 0..32 {
                ag[i] += w * buf[i] as f32;
            }
        }
    }
    for c in 0..d_h {
        let (s, z) = params[c];
        out[c] += s * acc[c] + z * psum;
    }
}

struct Record {
    kernel: &'static str,
    isa: &'static str,
    bits: u8,
    ns_per_row: f64,
    tokens_per_s: f64,
}

fn record(
    records: &mut Vec<Record>,
    kernel: &'static str,
    isa: &'static str,
    bits: u8,
    mean_us: f64,
    rows: usize,
) {
    let ns_per_row = mean_us * 1e3 / rows as f64;
    let tokens_per_s = rows as f64 / (mean_us * 1e-6);
    println!("{kernel:<16} {isa:<7} {bits:>4} {ns_per_row:>12.1} {tokens_per_s:>14.3e}");
    records.push(Record { kernel, isa, bits, ns_per_row, tokens_per_s });
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "quick");
    let n_tokens: usize = args
        .iter()
        .filter_map(|a| a.parse().ok())
        .next()
        .unwrap_or(if quick { 512 } else { 4096 });
    assert_eq!(n_tokens % 32, 0, "token count must be a multiple of the 32-token chunk");
    let (warmup, reps) = if quick { (2, 8) } else { (10, 60) };

    eprintln!("[kernel_throughput] d_h {D_H}, {n_tokens} tokens, quick={quick}");
    let mut rng = Rng::new(0xBE7C);
    let keys: Vec<f32> = (0..n_tokens * D_H).map(|_| rng.next_normal()).collect();
    let vals: Vec<f32> = (0..n_tokens * D_H).map(|_| rng.next_normal()).collect();
    let q: Vec<f32> = (0..D_H).map(|_| rng.next_normal()).collect();
    let p: Vec<f32> = {
        let mut w: Vec<f32> = (0..n_tokens).map(|_| rng.next_f32()).collect();
        let s: f32 = w.iter().sum();
        w.iter_mut().for_each(|v| *v /= s);
        w
    };

    // The ISA axis: every arm this host can run, scalar first. SIMD arms
    // are timed through the `*_with_isa` entry points, so one bench run
    // covers the whole dispatch matrix regardless of INNERQ_ISA.
    let arms = dispatch::supported();
    eprintln!(
        "[kernel_throughput] isa arms: {} (detected: {})",
        arms.iter().map(|a| a.name()).collect::<Vec<_>>().join(","),
        dispatch::detected().name(),
    );

    println!(
        "{:<16} {:<7} {:>4} {:>12} {:>14}",
        "kernel", "isa", "bits", "ns/row", "tokens/s"
    );
    let mut records: Vec<Record> = Vec::new();

    // FP32 baselines for context (one entry each, bits recorded as 32; the
    // f32 path has no dispatch arms, so it is recorded as scalar).
    let mut scores = vec![0f32; n_tokens];
    let s = time_us(warmup, reps, || {
        gemv_fp::qk_fp(&q, &keys, D_H, &mut scores);
        scores[0]
    });
    record(&mut records, "qk_fp", "scalar", 32, s.mean_us, n_tokens);
    let mut ctx = vec![0f32; D_H];
    let s = time_us(warmup, reps, || {
        ctx.iter_mut().for_each(|v| *v = 0.0);
        gemv_fp::pv_fp(&p, &vals, D_H, &mut ctx);
        ctx[0]
    });
    record(&mut records, "pv_fp", "scalar", 32, s.mean_us, n_tokens);

    for bits in [2u8, 3, 4] {
        // ---- key kernel: blocked vs scalar reference ----
        let mut kseg = InnerKeySegment::new(D_H, bits, Mode::Sym);
        for row in keys.chunks_exact(D_H) {
            kseg.append_token(row);
        }
        // AoS (scale, zeff) pairs for the pre-PR production variant.
        let aos: Vec<(f32, f32)> =
            kseg.scales.iter().copied().zip(kseg.zeffs.iter().copied()).collect();
        let mut fast = vec![0f32; n_tokens];
        let mut refr = vec![0f32; n_tokens];
        let mut prev = vec![0f32; n_tokens];
        qk_inner(&q, &kseg.codes, &kseg.scales, &kseg.zeffs, bits, D_H, &mut fast);
        qk_inner_ref(&q, &kseg.codes, &kseg.scales, &kseg.zeffs, bits, D_H, &mut refr);
        qk_inner_prev(&q, &kseg.codes, &aos, bits, D_H, &mut prev);
        assert_eq!(fast, refr, "qk dispatched/reference bit-identity violated at {bits} bits");
        assert_eq!(fast, prev, "qk dispatched/pre-PR bit-identity violated at {bits} bits");

        for &isa in &arms {
            let mut out = vec![0f32; n_tokens];
            qk_inner_with_isa(isa, &q, &kseg.codes, &kseg.scales, &kseg.zeffs, bits, D_H, &mut out);
            assert_eq!(
                out, refr,
                "qk {isa} arm/reference bit-identity violated at {bits} bits"
            );
            let s = time_us(warmup, reps, || {
                qk_inner_with_isa(
                    isa, &q, &kseg.codes, &kseg.scales, &kseg.zeffs, bits, D_H, &mut out,
                );
                out[0]
            });
            record(&mut records, "qk_inner", isa.name(), bits, s.mean_us, n_tokens);
        }
        let s = time_us(warmup, reps, || {
            qk_inner_prev(&q, &kseg.codes, &aos, bits, D_H, &mut prev);
            prev[0]
        });
        record(&mut records, "qk_inner_prev", "scalar", bits, s.mean_us, n_tokens);
        let s = time_us(warmup, reps, || {
            qk_inner_ref(&q, &kseg.codes, &kseg.scales, &kseg.zeffs, bits, D_H, &mut refr);
            refr[0]
        });
        record(&mut records, "qk_inner_ref", "scalar", bits, s.mean_us, n_tokens);

        // ---- value kernel: blocked vs scalar reference, over all chunks ----
        let mut vseg = InnerValSegment::new(D_H, bits, Mode::Sym);
        for chunk in vals.chunks_exact(32 * D_H) {
            vseg.append_chunk(chunk);
        }
        let chunk_bytes = 32 * (D_H / 32) * packed_len(32, bits);
        let n_chunks = n_tokens / 32;
        let vaos: Vec<(f32, f32)> =
            vseg.scales.iter().copied().zip(vseg.zeffs.iter().copied()).collect();
        // variant: 0 = dispatched entry point, 1 = pre-PR production shape,
        // 2 = scalar ref, 3 = explicit ISA arm (`isa` is only read here).
        let run_pv = |out: &mut [f32], variant: usize, isa: Isa| {
            out.iter_mut().for_each(|v| *v = 0.0);
            for k in 0..n_chunks {
                let pk = &p[k * 32..(k + 1) * 32];
                let ck = &vseg.codes[k * chunk_bytes..];
                let sk = &vseg.scales[k * D_H..(k + 1) * D_H];
                let zk = &vseg.zeffs[k * D_H..(k + 1) * D_H];
                match variant {
                    0 => pv_inner_chunk(pk, ck, sk, zk, bits, D_H, out),
                    1 => pv_inner_chunk_prev(pk, ck, &vaos[k * D_H..(k + 1) * D_H], bits, D_H, out),
                    2 => pv_inner_chunk_ref(pk, ck, sk, zk, bits, D_H, out),
                    _ => pv_inner_chunk_with_isa(isa, pk, ck, sk, zk, bits, D_H, out),
                }
            }
        };
        let mut fast_ctx = vec![0f32; D_H];
        let mut prev_ctx = vec![0f32; D_H];
        let mut ref_ctx = vec![0f32; D_H];
        run_pv(&mut fast_ctx, 0, Isa::Scalar);
        run_pv(&mut prev_ctx, 1, Isa::Scalar);
        run_pv(&mut ref_ctx, 2, Isa::Scalar);
        assert_eq!(fast_ctx, ref_ctx, "pv dispatched/reference bit-identity violated at {bits} bits");
        assert_eq!(fast_ctx, prev_ctx, "pv dispatched/pre-PR bit-identity violated at {bits} bits");

        for &isa in &arms {
            let mut arm_ctx = vec![0f32; D_H];
            run_pv(&mut arm_ctx, 3, isa);
            assert_eq!(
                arm_ctx, ref_ctx,
                "pv {isa} arm/reference bit-identity violated at {bits} bits"
            );
            let s = time_us(warmup, reps, || {
                run_pv(&mut arm_ctx, 3, isa);
                arm_ctx[0]
            });
            record(&mut records, "pv_inner", isa.name(), bits, s.mean_us, n_tokens);
        }
        let s = time_us(warmup, reps, || {
            run_pv(&mut prev_ctx, 1, Isa::Scalar);
            prev_ctx[0]
        });
        record(&mut records, "pv_inner_prev", "scalar", bits, s.mean_us, n_tokens);
        let s = time_us(warmup, reps, || {
            run_pv(&mut ref_ctx, 2, Isa::Scalar);
            ref_ctx[0]
        });
        record(&mut records, "pv_inner_ref", "scalar", bits, s.mean_us, n_tokens);

        // ---- outer (KIVI) key kernel: blocked vs scalar reference ----
        // The reference doubles as the pre-blocking production shape, so
        // the blocked-vs-ref delta is the honest baseline comparison.
        let mut oseg = OuterKeySegment::new(D_H, bits, Mode::Asym);
        for chunk in keys.chunks_exact(32 * D_H) {
            oseg.append_chunk(chunk);
        }
        let mut oscr = vec![0f32; D_H];
        let mut ofast = vec![0f32; n_tokens];
        let mut orefr = vec![0f32; n_tokens];
        // variant: 0 = dispatched entry point, 1 = scalar reference,
        // 2 = explicit ISA arm (`isa` is only read here).
        let run_qk_outer = |out: &mut [f32], scratch: &mut [f32], variant: usize, isa: Isa| {
            let row_bytes = (D_H / 32) * packed_len(32, bits);
            let chunk_bytes = 32 * row_bytes;
            for k in 0..n_tokens / 32 {
                let ck = &oseg.codes[k * chunk_bytes..];
                let sk = &oseg.scales[k * D_H..(k + 1) * D_H];
                let zk = &oseg.zeffs[k * D_H..(k + 1) * D_H];
                let ok = &mut out[k * 32..(k + 1) * 32];
                match variant {
                    0 => qk_outer_chunk(&q, ck, sk, zk, bits, D_H, scratch, ok),
                    1 => qk_outer_chunk_ref(&q, ck, sk, zk, bits, D_H, scratch, ok),
                    _ => qk_outer_chunk_with_isa(isa, &q, ck, sk, zk, bits, D_H, scratch, ok),
                }
            }
        };
        run_qk_outer(&mut ofast, &mut oscr, 0, Isa::Scalar);
        run_qk_outer(&mut orefr, &mut oscr, 1, Isa::Scalar);
        assert_eq!(
            ofast, orefr,
            "qk_outer dispatched/reference bit-identity violated at {bits} bits"
        );

        for &isa in &arms {
            let mut arm_out = vec![0f32; n_tokens];
            run_qk_outer(&mut arm_out, &mut oscr, 2, isa);
            assert_eq!(
                arm_out, orefr,
                "qk_outer {isa} arm/reference bit-identity violated at {bits} bits"
            );
            let s = time_us(warmup, reps, || {
                run_qk_outer(&mut arm_out, &mut oscr, 2, isa);
                arm_out[0]
            });
            record(&mut records, "qk_outer", isa.name(), bits, s.mean_us, n_tokens);
        }
        let s = time_us(warmup, reps, || {
            run_qk_outer(&mut orefr, &mut oscr, 1, Isa::Scalar);
            orefr[0]
        });
        record(&mut records, "qk_outer_ref", "scalar", bits, s.mean_us, n_tokens);
    }

    // SIMD-vs-scalar speedup summary per (kernel, bits) cell. Informational
    // (wall-clock on shared runners is too noisy for a hard gate here); the
    // trajectory check reads the per-arm cells from BENCH_kernels.json.
    for kernel in ["qk_inner", "pv_inner", "qk_outer"] {
        for bits in [2u8, 3, 4] {
            let scalar = records
                .iter()
                .find(|r| r.kernel == kernel && r.isa == "scalar" && r.bits == bits);
            let Some(scalar) = scalar else { continue };
            for r in records
                .iter()
                .filter(|r| r.kernel == kernel && r.bits == bits && r.isa != "scalar")
            {
                println!(
                    "[speedup] {kernel:<10} b{bits} {:<7} {:.2}x vs scalar",
                    r.isa,
                    scalar.ns_per_row / r.ns_per_row
                );
            }
        }
    }

    // Machine-readable trajectory record.
    let results: Vec<Json> = records
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("kernel", Json::str(r.kernel)),
                ("isa", Json::str(r.isa)),
                ("bits", Json::Num(r.bits as f64)),
                ("d_h", Json::Num(D_H as f64)),
                ("n_tokens", Json::Num(n_tokens as f64)),
                ("ns_per_row", Json::Num(r.ns_per_row)),
                ("tokens_per_s", Json::Num(r.tokens_per_s)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("kernel_throughput")),
        ("quick", Json::Bool(quick)),
        ("d_h", Json::Num(D_H as f64)),
        ("n_tokens", Json::Num(n_tokens as f64)),
        ("results", Json::Arr(results)),
    ]);
    let path = "BENCH_kernels.json";
    std::fs::write(path, doc.dump()).expect("write BENCH_kernels.json");
    eprintln!("[kernel_throughput] wrote {path}");
}
