//! Table 4: latency breakdown (µs) of the fused dequantize-GEMV kernels for
//! one Llama-3.1-8B layer (32 q heads, 8 KV heads, d_h 128, batch 1) across
//! sequence lengths, for the key op (Eq. 3), the value op (Eq. 5) and total.
//!
//! Protocol mirrors the paper (§5.3): warmup then averaged timed reps
//! (counts scaled to the single-core CPU testbed — see rust/benches/common).
//!
//! ```bash
//! cargo bench --bench table4_gemv            # full table
//! cargo bench --bench table4_gemv 512 2048   # subset of lengths
//! ```

mod common;

use common::*;
use innerq::kernels::gemv_fp;
use innerq::util::stats::time_us;

fn main() {
    let args: Vec<usize> =
        std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let lengths: Vec<usize> = if args.is_empty() { LENGTHS.to_vec() } else { args };

    println!("Table 4 (measured, CPU): fused dequant-GEMV latency (µs), one Llama-3.1-8B layer");

    let mut rows: Vec<(String, String, Vec<f64>)> = Vec::new();
    for &n in &lengths {
        let d = layer_data(n, 7);
        let segs = build_segments(&d, n);
        let mut scratch = vec![0f32; D_H];
        let mut scores = vec![0f32; n];
        let mut ctx = vec![0f32; D_H];
        let (w, r) = reps_for(n);

        let mut push = |cache: &str, method: &str, us: f64| {
            if let Some(row) = rows.iter_mut().find(|(c, m, _)| c == cache && m == method) {
                row.2.push(us);
            } else {
                rows.push((cache.into(), method.into(), vec![us]));
            }
        };

        // ---- key op: all 32 query heads against their KV head's cache ----
        let s = time_us(w, r, || {
            for hq in 0..N_Q {
                let hk = hq / (N_Q / N_KV);
                gemv_fp::qk_fp(&d.q[hq * D_H..(hq + 1) * D_H], &d.keys[hk], D_H, &mut scores);
            }
            scores[0]
        });
        push("key", "baseline_fp16", s.mean_us);

        let s = time_us(w, r, || {
            for hq in 0..N_Q {
                let hk = hq / (N_Q / N_KV);
                segs.outer_k[hk].scores(&d.q[hq * D_H..(hq + 1) * D_H], &mut scratch, &mut scores);
            }
            scores[0]
        });
        push("key", "kivi", s.mean_us);

        let s = time_us(w, r, || {
            for hq in 0..N_Q {
                let hk = hq / (N_Q / N_KV);
                segs.turbo_k[hk].scores(&d.q[hq * D_H..(hq + 1) * D_H], &mut scores);
            }
            scores[0]
        });
        push("key", "turboquant", s.mean_us);

        let s = time_us(w, r, || {
            for hq in 0..N_Q {
                let hk = hq / (N_Q / N_KV);
                segs.inner_k[hk].scores(&d.q[hq * D_H..(hq + 1) * D_H], &mut scores);
            }
            scores[0]
        });
        push("key", "innerq_all", s.mean_us);

        // ---- value op: P·V per KV head, repeated per attending q head ----
        let rep = N_Q / N_KV;
        let s = time_us(w, r, || {
            for hk in 0..N_KV {
                for _ in 0..rep {
                    ctx.iter_mut().for_each(|v| *v = 0.0);
                    gemv_fp::pv_fp(&d.p, &d.vals[hk], D_H, &mut ctx);
                }
            }
            ctx[0]
        });
        push("value", "baseline_fp16", s.mean_us);

        let s = time_us(w, r, || {
            for hk in 0..N_KV {
                for _ in 0..rep {
                    ctx.iter_mut().for_each(|v| *v = 0.0);
                    segs.outer_v[hk].accumulate(&d.p, &mut ctx);
                }
            }
            ctx[0]
        });
        push("value", "kivi", s.mean_us);

        let s = time_us(w, r, || {
            for hk in 0..N_KV {
                for _ in 0..rep {
                    ctx.iter_mut().for_each(|v| *v = 0.0);
                    let mut acc = vec![0f32; D_H];
                    segs.turbo_v[hk].accumulate_rotated(&d.p, &mut acc);
                    segs.turbo_v[hk].finalize_into(acc, &mut ctx);
                }
            }
            ctx[0]
        });
        push("value", "turboquant", s.mean_us);

        for (name, vsegs) in [
            ("innerq_base", &segs.inner_v3),
            ("innerq_hybrid", &segs.inner_v2h),
            ("innerq_small", &segs.inner_v2),
        ] {
            let s = time_us(w, r, || {
                for hk in 0..N_KV {
                    for _ in 0..rep {
                        ctx.iter_mut().for_each(|v| *v = 0.0);
                        vsegs[hk].accumulate(&d.p, &mut ctx);
                    }
                }
                ctx[0]
            });
            push("value", name, s.mean_us);
        }
        eprintln!("  [n={n}] done");
    }

    let get = |cache: &str, method: &str| -> &Vec<f64> {
        &rows.iter().find(|(c, m, _)| c == cache && m == method).unwrap().2
    };
    let fmt = |v: &[f64]| v.iter().map(|x| format!("{x:>9.0}")).collect::<String>();
    println!("{:<28} {}", "seq len", lengths.iter().map(|n| format!("{n:>9}")).collect::<String>());
    println!("Key cache (Eq. 3):");
    for m in ["baseline_fp16", "kivi", "turboquant", "innerq_all"] {
        println!("  {:<26} {}", m, fmt(get("key", m)));
    }
    println!("Value cache (Eq. 5):");
    for m in ["baseline_fp16", "kivi", "turboquant", "innerq_base", "innerq_hybrid", "innerq_small"] {
        println!("  {:<26} {}", m, fmt(get("value", m)));
    }
    println!("Total:");
    for (m, key_m) in [
        ("baseline_fp16", "baseline_fp16"),
        ("kivi", "kivi"),
        ("turboquant", "turboquant"),
        ("innerq_base", "innerq_all"),
        ("innerq_hybrid", "innerq_all"),
        ("innerq_small", "innerq_all"),
    ] {
        let k = get("key", key_m);
        let v = get("value", m);
        let tot: Vec<f64> = k.iter().zip(v).map(|(a, b)| a + b).collect();
        println!("  {:<26} {}", m, fmt(&tot));
    }
}
