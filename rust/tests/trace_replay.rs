//! Trace-replay harness tests over the fake-model artifacts: the
//! byte-identity determinism contract (same trace + seed ⇒ identical report
//! across worker counts), and sanity of the overload behavior the harness
//! exists to measure (higher arrival rate ⇒ no better tail latency).

use innerq::coordinator::{Engine, Policy, Scheduler};
use innerq::runtime::Manifest;
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::workload::replay::{replay, CostModel, Outcome, ReplayReport};
use innerq::workload::trace::{
    generate_multi_turn, generate_timed, Arrival, MultiTurnTraceConfig, TimedRequest,
    TimedTraceConfig,
};
use innerq::QuantMethod;
use std::collections::BTreeMap;

fn fake_scheduler(tag: &str, budget: usize, workers: usize, policy: Policy) -> Scheduler {
    fake_scheduler_cfg(tag, QuantMethod::InnerQBase.config(), budget, workers, policy)
}

fn fake_scheduler_cfg(
    tag: &str,
    cfg: innerq::quant::MethodConfig,
    budget: usize,
    workers: usize,
    policy: Policy,
) -> Scheduler {
    let dir = write_fake_artifacts(tag, '7');
    let manifest = Manifest::load(&dir).expect("fake manifest");
    let mut engine = Engine::new(manifest, cfg).expect("engine");
    engine.set_workers(workers);
    let mut sched = Scheduler::new(engine, budget);
    sched.set_policy(policy);
    sched
}

/// InnerQBase with serving-scale windows shrunk to fit the 128-token
/// fake-model bucket: under the default 32-sink + 96-recent windows a
/// whole fake prompt lives in the fp windows, so a session prefix would
/// hold no quantized middle and the store would have nothing to share.
fn small_window_cfg() -> innerq::quant::MethodConfig {
    let mut cfg = QuantMethod::InnerQBase.config();
    cfg.w_sink = 4;
    cfg.w_recent = 8;
    cfg
}

fn stress_trace(rate_rps: f64, n: usize) -> Vec<innerq::workload::trace::TimedRequest> {
    generate_timed(&TimedTraceConfig {
        n_requests: n,
        arrival: Arrival::Poisson { rate_rps },
        priority_mix: [1.0, 2.0, 1.0],
        // Tight interactive deadlines + tight budget force admissions,
        // preemptions, and expiries to all appear in the replay.
        deadlines_us: [Some(200_000), None, None],
        seed: 42,
        ..TimedTraceConfig::default()
    })
}

fn run(tag: &str, workers: usize, policy: Policy, rate: f64) -> ReplayReport {
    let trace = stress_trace(rate, 48);
    let mut sched = fake_scheduler(tag, 64_000, workers, policy);
    replay(&mut sched, &trace, &CostModel::default()).expect("replay")
}

#[test]
fn replay_is_byte_identical_across_worker_counts() {
    for policy in [Policy::Fifo, Policy::Slo] {
        let a = run("det_w1", 1, policy, 400.0).to_json().dump();
        let b = run("det_w4", 4, policy, 400.0).to_json().dump();
        assert!(!a.is_empty());
        assert_eq!(a, b, "{policy:?}: workers=4 replay diverged from workers=1");
    }
}

#[test]
fn replay_is_reproducible_within_a_worker_count() {
    let a = run("rep_a", 2, Policy::Slo, 400.0).to_json().dump();
    let b = run("rep_b", 2, Policy::Slo, 400.0).to_json().dump();
    assert_eq!(a, b, "same seed + same workers must reproduce exactly");
}

#[test]
fn every_request_reaches_a_terminal_state() {
    let report = run("terminal", 1, Policy::Slo, 800.0);
    let n = report.records.len();
    let accounted =
        report.count(Outcome::Ok) + report.count(Outcome::Rejected) + report.count(Outcome::Expired);
    assert_eq!(accounted, n, "every record needs a terminal outcome");
    for r in &report.records {
        assert!(r.outcome.is_some(), "request {} left pending", r.id);
        if r.outcome == Some(Outcome::Ok) {
            assert!(r.admitted_us.is_some());
            assert!(r.finished_us.unwrap() >= r.admitted_us.unwrap());
            assert!(r.n_generated > 0);
        }
    }
    assert!(report.end_us > 0);
    assert!(report.ticks > 0);
}

#[test]
fn overload_degrades_tail_latency_not_correctness() {
    // The harness's whole point: at a fixed budget, pushing the arrival
    // rate far past capacity must not corrupt results — it shows up as
    // queueing delay in the tail instead.
    let calm = run("calm", 1, Policy::Fifo, 20.0);
    let slammed = run("slam", 1, Policy::Fifo, 4000.0);
    let calm_p99 = calm.overall().e2e.summary().p99_us;
    let slam_p99 = slammed.overall().e2e.summary().p99_us;
    assert!(
        slam_p99 >= calm_p99,
        "overload p99 e2e ({slam_p99}µs) should not beat calm p99 ({calm_p99}µs)"
    );
    assert!(calm.count(Outcome::Ok) > 0);
    assert!(slammed.count(Outcome::Ok) > 0, "overload must still complete work");
}

#[test]
fn slo_policy_protects_interactive_tail_under_overload() {
    // Same overloaded trace under both policies: the SLO policy must not
    // serve interactive requests a worse median TTFT than FIFO does (it
    // admits them first and may preempt batch work for them).
    let fifo = run("pol_fifo", 1, Policy::Fifo, 2000.0);
    let slo = run("pol_slo", 1, Policy::Slo, 2000.0);
    let fifo_ttft = fifo.class(innerq::coordinator::Priority::Interactive).ttft.summary();
    let slo_ttft = slo.class(innerq::coordinator::Priority::Interactive).ttft.summary();
    // Guard against a degenerate trace where nothing interactive ran.
    assert!(fifo_ttft.count > 0 && slo_ttft.count > 0);
    assert!(
        slo_ttft.p50_us <= fifo_ttft.p50_us,
        "SLO median interactive TTFT ({}) worse than FIFO ({})",
        slo_ttft.p50_us,
        fifo_ttft.p50_us
    );
}

// ---------------------------------------------------------------------------
// Multi-turn (shared-session-prefix) trace family.
// ---------------------------------------------------------------------------

/// A chat-style trace: `n` requests round-robined over a handful of sessions,
/// each session's requests opening with the same context prefix. No deadlines
/// so every request reaches `Ok` and text comparison is total.
fn multi_turn_trace(n: usize, rate_rps: f64) -> Vec<TimedRequest> {
    generate_multi_turn(&MultiTurnTraceConfig {
        base: TimedTraceConfig {
            n_requests: n,
            arrival: Arrival::Poisson { rate_rps },
            seed: 7,
            ..TimedTraceConfig::default()
        },
        ..MultiTurnTraceConfig::default()
    })
}

fn run_multi_turn(tag: &str, workers: usize, share: bool) -> ReplayReport {
    let trace = multi_turn_trace(48, 400.0);
    let mut sched = fake_scheduler_cfg(tag, small_window_cfg(), 64_000, workers, Policy::Slo);
    sched.set_prefix_share(share);
    replay(&mut sched, &trace, &CostModel::default()).expect("replay")
}

/// Within one prefix-share setting, the multi-turn replay report must be
/// byte-identical across worker counts {1, 2, 4, 8} — the store's dedup and
/// refcount decisions may not depend on intra-tick parallelism.
#[test]
fn multi_turn_replay_is_byte_identical_across_worker_counts() {
    for share in [true, false] {
        let reference = run_multi_turn(&format!("mt_{share}_w1"), 1, share).to_json().dump();
        assert!(!reference.is_empty());
        for workers in [2usize, 4, 8] {
            let got =
                run_multi_turn(&format!("mt_{share}_w{workers}"), workers, share).to_json().dump();
            assert_eq!(
                got, reference,
                "share={share}: workers={workers} replay diverged from workers=1"
            );
        }
    }
}

/// Sharing is an accounting optimization, never a numerics change: with the
/// prefix store on vs off, every request must generate the identical text.
/// (The *reports* may legitimately differ — sharing changes admission byte
/// charges and tick costs — so this compares completions, not JSON.)
#[test]
fn multi_turn_outputs_identical_across_prefix_share_settings() {
    let texts = |tag: &str, share: bool| -> BTreeMap<u64, String> {
        let trace = multi_turn_trace(32, 400.0);
        let mut sched = fake_scheduler_cfg(tag, small_window_cfg(), 64_000, 2, Policy::Slo);
        sched.set_prefix_share(share);
        for t in &trace {
            sched.submit_at(t.req.clone(), t.arrival_us);
        }
        sched
            .run_to_completion()
            .expect("run")
            .into_iter()
            .map(|c| {
                assert!(c.error.is_none(), "request {} failed: {:?}", c.id, c.error);
                (c.id, c.text)
            })
            .collect()
    };
    let on = texts("mt_text_on", true);
    let off = texts("mt_text_off", false);
    assert_eq!(on.len(), 32);
    assert_eq!(on, off, "prefix sharing changed generated text");
}

/// The multi-turn family actually exercises the store: with sharing on the
/// replay must record prefix hits and shared bytes; with it off, none.
#[test]
fn multi_turn_replay_records_prefix_hits_only_when_sharing() {
    let on = run_multi_turn("mt_hits_on", 1, true);
    let off = run_multi_turn("mt_hits_off", 1, false);
    assert!(on.metrics.prefix_hits > 0, "multi-turn trace must produce prefix hits");
    assert!(on.metrics.prefix_bytes_shared > 0);
    assert!(on.records.iter().any(|r| r.prefix_hits > 0));
    assert_eq!(off.metrics.prefix_hits, 0, "sharing disabled must never hit");
    assert_eq!(off.metrics.prefix_bytes_shared, 0);
    assert!(off.records.iter().all(|r| r.prefix_hits == 0));
}

// ---------------------------------------------------------------------------
// Socket-vs-replay oracle: the staged server front end is just transport.
// Driving the same greedy, deadline-free trace through real sockets must
// produce byte-identical completion text to the virtual-clock replay, at
// every IO-worker count.
// ---------------------------------------------------------------------------

/// Greedy, deadline-free, ample-budget trace: completion text is a pure
/// function of each prompt, so socket timing and IO-worker interleaving
/// cannot legitimately change it.
fn oracle_trace(n: usize) -> Vec<TimedRequest> {
    generate_timed(&TimedTraceConfig {
        n_requests: n,
        arrival: Arrival::Poisson { rate_rps: 400.0 },
        seed: 19,
        ..TimedTraceConfig::default()
    })
}

/// Replay side of the oracle: id → completion text, all requests `Ok`.
fn replay_texts(trace: &[TimedRequest]) -> BTreeMap<u64, String> {
    let mut sched = fake_scheduler("sock_oracle", 1 << 30, 2, Policy::Fifo);
    let report = replay(&mut sched, trace, &CostModel::default()).expect("replay");
    assert_eq!(report.count(Outcome::Ok), trace.len(), "oracle must complete everything");
    report.records.iter().map(|r| (r.id, r.text.clone())).collect()
}

/// Socket side: run a live staged server and push the whole trace through
/// real connections (pipelined, tagged with the trace ids), collecting
/// id → completion text off the wire.
fn socket_texts(tag: &str, trace: &[TimedRequest], io_workers: usize) -> BTreeMap<u64, String> {
    use innerq::server::{serve_with, ServerConfig};
    use std::io::{BufRead, BufReader, Write};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{mpsc, Arc};

    let dir = write_fake_artifacts(tag, '7');
    let stop = Arc::new(AtomicBool::new(false));
    let stop_srv = stop.clone();
    let (bound_tx, bound_rx) = mpsc::channel();
    let server = std::thread::spawn(move || {
        let manifest = Manifest::load(&dir).expect("fake manifest");
        let mut engine = Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
        engine.set_workers(2);
        let sched = Scheduler::new(engine, 1 << 30);
        let cfg = ServerConfig { io_workers, admin_addr: None };
        serve_with(sched, "127.0.0.1:0", cfg, stop_srv, move |b| {
            let _ = bound_tx.send(b.data);
        })
    });
    let addr = bound_rx.recv().expect("server bound");

    // Deal the trace over a few connections; each pipelines its share in
    // one burst and then drains its completions, matched by tag.
    let n_conns = 3usize.min(trace.len()).max(1);
    let mut batches: Vec<Vec<String>> = vec![Vec::new(); n_conns];
    for (i, t) in trace.iter().enumerate() {
        batches[i % n_conns].push(
            innerq::util::json::Json::obj(vec![
                ("prompt", innerq::util::json::Json::str(&t.req.prompt)),
                ("max_new_tokens", innerq::util::json::Json::Num(t.req.max_new_tokens as f64)),
                ("tag", innerq::util::json::Json::str(&t.req.id.to_string())),
            ])
            .dump(),
        );
    }
    let clients: Vec<_> = batches
        .into_iter()
        .map(|batch| {
            std::thread::spawn(move || {
                let mut conn = std::net::TcpStream::connect(addr).expect("connect");
                let mut reader = BufReader::new(conn.try_clone().expect("clone"));
                let mut payload = String::new();
                for line in &batch {
                    payload.push_str(line);
                    payload.push('\n');
                }
                conn.write_all(payload.as_bytes()).expect("send");
                conn.flush().expect("flush");
                let mut out = BTreeMap::new();
                for _ in 0..batch.len() {
                    let mut s = String::new();
                    let n = reader.read_line(&mut s).expect("read");
                    assert!(n > 0, "server closed mid-trace");
                    let j = innerq::util::json::Json::parse(&s).expect("response parses");
                    assert_eq!(j.get("error").as_str(), None, "unexpected error: {s}");
                    let id: u64 = j.get("tag").as_str().expect("tag").parse().expect("tag id");
                    out.insert(id, j.get("text").as_str().unwrap_or("").to_string());
                }
                out
            })
        })
        .collect();
    let mut texts = BTreeMap::new();
    for c in clients {
        texts.extend(c.join().expect("client thread"));
    }
    stop.store(true, Ordering::Relaxed);
    server.join().expect("server thread").expect("serve result");
    texts
}

#[test]
fn socket_completions_match_the_replay_oracle_at_every_io_worker_count() {
    let trace = oracle_trace(24);
    let oracle = replay_texts(&trace);
    for io_workers in [1usize, 2, 4] {
        let got = socket_texts(&format!("sock_w{io_workers}"), &trace, io_workers);
        assert_eq!(got.len(), trace.len(), "io_workers={io_workers}: request lost or duplicated");
        assert_eq!(
            got, oracle,
            "io_workers={io_workers}: socket completions diverged from the replay oracle"
        );
    }
}
