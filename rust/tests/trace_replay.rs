//! Trace-replay harness tests over the fake-model artifacts: the
//! byte-identity determinism contract (same trace + seed ⇒ identical report
//! across worker counts), and sanity of the overload behavior the harness
//! exists to measure (higher arrival rate ⇒ no better tail latency).

use innerq::coordinator::{Engine, Policy, Scheduler};
use innerq::runtime::Manifest;
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::workload::replay::{replay, CostModel, Outcome, ReplayReport};
use innerq::workload::trace::{generate_timed, Arrival, TimedTraceConfig};
use innerq::QuantMethod;

fn fake_scheduler(tag: &str, budget: usize, workers: usize, policy: Policy) -> Scheduler {
    let dir = write_fake_artifacts(tag, '7');
    let manifest = Manifest::load(&dir).expect("fake manifest");
    let mut engine = Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
    engine.set_workers(workers);
    let mut sched = Scheduler::new(engine, budget);
    sched.set_policy(policy);
    sched
}

fn stress_trace(rate_rps: f64, n: usize) -> Vec<innerq::workload::trace::TimedRequest> {
    generate_timed(&TimedTraceConfig {
        n_requests: n,
        arrival: Arrival::Poisson { rate_rps },
        priority_mix: [1.0, 2.0, 1.0],
        // Tight interactive deadlines + tight budget force admissions,
        // preemptions, and expiries to all appear in the replay.
        deadlines_us: [Some(200_000), None, None],
        seed: 42,
        ..TimedTraceConfig::default()
    })
}

fn run(tag: &str, workers: usize, policy: Policy, rate: f64) -> ReplayReport {
    let trace = stress_trace(rate, 48);
    let mut sched = fake_scheduler(tag, 64_000, workers, policy);
    replay(&mut sched, &trace, &CostModel::default()).expect("replay")
}

#[test]
fn replay_is_byte_identical_across_worker_counts() {
    for policy in [Policy::Fifo, Policy::Slo] {
        let a = run("det_w1", 1, policy, 400.0).to_json().dump();
        let b = run("det_w4", 4, policy, 400.0).to_json().dump();
        assert!(!a.is_empty());
        assert_eq!(a, b, "{policy:?}: workers=4 replay diverged from workers=1");
    }
}

#[test]
fn replay_is_reproducible_within_a_worker_count() {
    let a = run("rep_a", 2, Policy::Slo, 400.0).to_json().dump();
    let b = run("rep_b", 2, Policy::Slo, 400.0).to_json().dump();
    assert_eq!(a, b, "same seed + same workers must reproduce exactly");
}

#[test]
fn every_request_reaches_a_terminal_state() {
    let report = run("terminal", 1, Policy::Slo, 800.0);
    let n = report.records.len();
    let accounted =
        report.count(Outcome::Ok) + report.count(Outcome::Rejected) + report.count(Outcome::Expired);
    assert_eq!(accounted, n, "every record needs a terminal outcome");
    for r in &report.records {
        assert!(r.outcome.is_some(), "request {} left pending", r.id);
        if r.outcome == Some(Outcome::Ok) {
            assert!(r.admitted_us.is_some());
            assert!(r.finished_us.unwrap() >= r.admitted_us.unwrap());
            assert!(r.n_generated > 0);
        }
    }
    assert!(report.end_us > 0);
    assert!(report.ticks > 0);
}

#[test]
fn overload_degrades_tail_latency_not_correctness() {
    // The harness's whole point: at a fixed budget, pushing the arrival
    // rate far past capacity must not corrupt results — it shows up as
    // queueing delay in the tail instead.
    let calm = run("calm", 1, Policy::Fifo, 20.0);
    let slammed = run("slam", 1, Policy::Fifo, 4000.0);
    let calm_p99 = calm.overall().e2e.summary().p99_us;
    let slam_p99 = slammed.overall().e2e.summary().p99_us;
    assert!(
        slam_p99 >= calm_p99,
        "overload p99 e2e ({slam_p99}µs) should not beat calm p99 ({calm_p99}µs)"
    );
    assert!(calm.count(Outcome::Ok) > 0);
    assert!(slammed.count(Outcome::Ok) > 0, "overload must still complete work");
}

#[test]
fn slo_policy_protects_interactive_tail_under_overload() {
    // Same overloaded trace under both policies: the SLO policy must not
    // serve interactive requests a worse median TTFT than FIFO does (it
    // admits them first and may preempt batch work for them).
    let fifo = run("pol_fifo", 1, Policy::Fifo, 2000.0);
    let slo = run("pol_slo", 1, Policy::Slo, 2000.0);
    let fifo_ttft = fifo.class(innerq::coordinator::Priority::Interactive).ttft.summary();
    let slo_ttft = slo.class(innerq::coordinator::Priority::Interactive).ttft.summary();
    // Guard against a degenerate trace where nothing interactive ran.
    assert!(fifo_ttft.count > 0 && slo_ttft.count > 0);
    assert!(
        slo_ttft.p50_us <= fifo_ttft.p50_us,
        "SLO median interactive TTFT ({}) worse than FIFO ({})",
        slo_ttft.p50_us,
        fifo_ttft.p50_us
    );
}
