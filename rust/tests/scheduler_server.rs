//! Scheduler + server behaviour tests over the fake-model artifacts
//! (`util::fakemodel`): no `make artifacts` required. The fake model emits
//! constant logits peaked at one token, which makes completions exactly
//! predictable while still driving prefill bucketing, cache append/attend
//! across layers and heads, continuous batching, the worker-pool fan-out,
//! and the TCP protocol.

use innerq::coordinator::{Engine, Policy, Priority, Request, SchedEvent, Scheduler};
use innerq::runtime::Manifest;
use innerq::server::{serve, serve_with, AdminClient, Client, ServerConfig};
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::QuantMethod;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

fn fake_scheduler(tag: &str, peak: char, budget: usize, workers: usize) -> Scheduler {
    let dir = write_fake_artifacts(tag, peak);
    let manifest = Manifest::load(&dir).expect("fake manifest");
    let mut engine = Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
    engine.set_workers(workers);
    Scheduler::new(engine, budget)
}

fn req(id: u64, prompt: &str, max_new_tokens: usize) -> Request {
    Request::new(id, prompt, max_new_tokens)
}

fn req_class(id: u64, prompt: &str, max_new_tokens: usize, p: Priority) -> Request {
    let mut r = Request::new(id, prompt, max_new_tokens);
    r.priority = p;
    r
}

#[test]
fn stop_token_is_excluded_from_completions() {
    // The fake head always argmaxes to '.': generation must stop
    // immediately with an EMPTY completion — the stop token itself used to
    // leak into `generated` and inflate n_generated.
    let mut sched = fake_scheduler("stop", '.', 1 << 30, 1);
    sched.submit(req(1, "a=11;?a=", 8));
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].text, "", "stop token must not appear in the text");
    assert_eq!(done[0].n_generated, 0);
    assert!(done[0].error.is_none());
    assert!(sched.metrics.decode_steps >= 1);
}

#[test]
fn generation_runs_to_max_tokens() {
    let mut sched = fake_scheduler("runmax", '7', 1 << 30, 1);
    sched.submit(req(1, "a=17;?a=", 5));
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].text, "77777");
    assert_eq!(done[0].n_generated, 5);
    assert_eq!(done[0].n_prompt, 8);
}

#[test]
fn pressure_preempts_younger_live_work_and_completes_everyone() {
    // Budget fits one sequence. The older request (lower id) arrives second,
    // so admission preempts the younger live sequence, requeues it, and both
    // finish.
    let mut sched = fake_scheduler("preempt", '7', 6000, 1);
    sched.submit(req(50, "a=1;?a=", 2));
    sched.submit(req(3, "b=2;?b=", 2));
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);
    for c in &done {
        assert_eq!(c.text, "77", "req {} got '{}'", c.id, c.text);
        assert!(c.error.is_none());
    }
    assert!(
        sched.metrics.preemptions >= 1,
        "the younger live sequence must have been preempted"
    );
}

#[test]
fn stale_reservation_cannot_livelock_admission() {
    // Regression: a reservation whose owner is not live (id 999 never had a
    // sequence) used to make `tick()` spin forever under pressure, because
    // the youngest victim was not found in `live` and nothing was ever
    // released. Now the stale reservation is dropped and admission proceeds.
    let mut sched = fake_scheduler("stale", '7', 6000, 1);
    assert_eq!(
        sched.pool.admit(999, 3000),
        innerq::cache::Admission::Admitted
    );
    sched.submit(req(1, "a=1;?a=", 2));
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].text, "77");
    assert_eq!(sched.metrics.stale_reservations, 1);
    assert_eq!(sched.metrics.preemptions, 0);
}

#[test]
fn oversized_requests_fail_with_an_error() {
    let mut sched = fake_scheduler("toolarge", '7', 6000, 1);
    sched.submit(req(1, "a=1;?a=", 200)); // estimate far over budget
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].n_generated, 0);
    assert!(done[0].error.as_deref().unwrap_or("").contains("budget"));
    assert_eq!(sched.metrics.rejected, 1);
}

#[test]
fn unencodable_prompts_fail_the_request_not_the_scheduler() {
    let mut sched = fake_scheduler("badprompt", '7', 1 << 30, 1);
    sched.submit(req(1, "Z!", 4)); // 'Z' is not in the model charset
    sched.submit(req(2, "a=1;?a=", 2));
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);
    let bad = done.iter().find(|c| c.id == 1).unwrap();
    assert!(bad.error.is_some());
    assert_eq!(bad.n_generated, 0);
    let good = done.iter().find(|c| c.id == 2).unwrap();
    assert_eq!(good.text, "77");
    assert!(good.error.is_none());
}

#[test]
fn completions_are_identical_across_worker_counts() {
    // workers=1 is the serial baseline; any pool size must produce the
    // same completions in the same order (the fan-out only changes which
    // thread computes each disjoint context slice).
    let prompts = ["a=41;?a=", "b=07;c=22;?c=", "d=99;?d=", "e=15;f=33;?f="];
    let run = |workers: usize, tag: &str| {
        let mut sched = fake_scheduler(tag, '3', 1 << 30, workers);
        for (i, p) in prompts.iter().enumerate() {
            sched.submit(req(i as u64, p, 4));
        }
        let mut done = sched.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        done.into_iter()
            .map(|c| (c.id, c.text, c.n_generated))
            .collect::<Vec<_>>()
    };
    let serial = run(1, "det1");
    assert_eq!(serial.len(), prompts.len());
    for (_, text, n) in &serial {
        assert_eq!(text, "3333");
        assert_eq!(*n, 4);
    }
    assert_eq!(run(4, "det4"), serial, "workers=4 diverged from serial");
}

// ---------------------------------------------------------------------------
// Preemption-policy matrix: FIFO default ordering, SLO priority rules, and
// deadline expiry. Budget 6000 fits exactly one est-4608 sequence
// (7-char prompt + 2 new tokens at the fake geometry), forcing contention.
// ---------------------------------------------------------------------------

#[test]
fn default_policy_reproduces_fifo_ordering() {
    // Under the default policy with one-sequence budget, requests complete
    // strictly in submission order, and a younger head never preempts older
    // live work (it parks) — today's FIFO semantics, exactly.
    let mut sched = fake_scheduler("fifo_order", '7', 6000, 1);
    for id in 0..5u64 {
        sched.submit(req(id, "a=1;?a=", 2));
    }
    let done = sched.run_to_completion().unwrap();
    let order: Vec<u64> = done.iter().map(|c| c.id).collect();
    assert_eq!(order, vec![0, 1, 2, 3, 4], "completions must leave in FIFO order");
    for c in &done {
        assert_eq!(c.text, "77");
        assert!(c.error.is_none());
    }
    assert_eq!(sched.metrics.preemptions, 0, "in-order arrivals never preempt");
}

#[test]
fn greedy_admission_fills_budget_in_one_tick() {
    // Regression for the one-prefill-per-tick bug: with budget to spare,
    // a burst of queued requests must all be admitted by the first tick
    // instead of serializing one admission per tick.
    let mut sched = fake_scheduler("greedy", '7', 1 << 30, 1);
    sched.record_events(true);
    for id in 0..4u64 {
        sched.submit(req(id, "a=1;?a=", 4));
    }
    sched.tick().unwrap();
    let admitted: Vec<u64> = sched
        .take_events()
        .into_iter()
        .filter_map(|e| match e {
            SchedEvent::Admitted { id, .. } => Some(id),
            _ => None,
        })
        .collect();
    assert_eq!(admitted, vec![0, 1, 2, 3], "burst must be admitted greedily in one tick");
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 4);
}

#[test]
fn slo_policy_admits_by_priority_not_arrival() {
    // Two queued requests, budget for one: the interactive request is
    // admitted first even though the batch request arrived earlier.
    let mut sched = fake_scheduler("slo_order", '7', 6000, 1);
    sched.set_policy(Policy::Slo);
    sched.submit(req_class(1, "a=1;?a=", 2, Priority::Batch));
    sched.submit(req_class(2, "b=2;?b=", 2, Priority::Interactive));
    let done = sched.run_to_completion().unwrap();
    let order: Vec<u64> = done.iter().map(|c| c.id).collect();
    assert_eq!(order, vec![2, 1], "interactive must complete before batch");
    for c in &done {
        assert!(c.error.is_none());
    }
    // No preemption was needed — the interactive request simply won the
    // admission race while both were queued.
    assert_eq!(sched.metrics.preemptions, 0);
}

#[test]
fn slo_policy_preempts_lower_class_but_never_inverts() {
    // Phase 1: a live batch-class sequence is preempted by an arriving
    // interactive request. Phase 2 (inversion check): a live interactive
    // sequence is NOT preempted by an arriving batch request — the batch
    // request parks until the interactive one finishes.
    let mut sched = fake_scheduler("slo_preempt", '7', 6000, 1);
    sched.set_policy(Policy::Slo);

    // Phase 1: batch live, interactive arrives.
    sched.submit(req_class(1, "a=1;?a=", 2, Priority::Batch));
    sched.tick().unwrap(); // admit batch
    sched.submit(req_class(2, "b=2;?b=", 2, Priority::Interactive));
    let done = sched.run_to_completion().unwrap();
    assert_eq!(
        sched.metrics.preemptions, 1,
        "interactive must preempt the live batch sequence"
    );
    let first = done.first().unwrap();
    assert_eq!(first.id, 2, "interactive completes first after preempting");
    assert_eq!(done.len(), 2, "the preempted batch request still completes");
    for c in &done {
        assert!(c.error.is_none(), "req {}: {:?}", c.id, c.error);
    }

    // Phase 2: interactive live, batch arrives — no inversion.
    sched.submit(req_class(10, "c=3;?c=", 2, Priority::Interactive));
    sched.tick().unwrap(); // admit interactive
    sched.submit(req_class(11, "d=4;?d=", 2, Priority::Batch));
    let done = sched.run_to_completion().unwrap();
    assert_eq!(
        sched.metrics.preemptions, 1,
        "a batch arrival must never preempt live interactive work"
    );
    assert_eq!(done.first().unwrap().id, 10, "interactive work runs to completion first");
    assert_eq!(done.len(), 2);
}

#[test]
fn equal_priority_never_preempts_under_slo() {
    // Same class on both sides: SLO preemption requires a strictly lower
    // class, so the later request parks exactly like FIFO.
    let mut sched = fake_scheduler("slo_equal", '7', 6000, 1);
    sched.set_policy(Policy::Slo);
    sched.submit(req_class(1, "a=1;?a=", 2, Priority::Standard));
    sched.tick().unwrap();
    sched.submit(req_class(2, "b=2;?b=", 2, Priority::Standard));
    let done = sched.run_to_completion().unwrap();
    assert_eq!(sched.metrics.preemptions, 0);
    assert_eq!(done.iter().map(|c| c.id).collect::<Vec<_>>(), vec![1, 2]);
}

#[test]
fn slo_bypass_admits_smaller_requests_with_a_starvation_bound() {
    // Budget 30000. A long interactive sequence (id 0, est 18944) is live; a
    // big interactive head (id 1, est 29184) can never fit beside it, so it
    // parks — it cannot preempt its own class. Four tiny batch requests
    // (est 4608) are queued behind it. The bypass lets smaller *lower-class*
    // requests use the spare budget, but only `bypass_limit` times per head:
    // with limit 2, exactly ids 2 and 3 slip past; 4 and 5 must wait until
    // the head itself has been admitted. With limit 0 nothing passes the
    // parked head at all.
    let run = |tag: &str, limit: u32| {
        let mut sched = fake_scheduler(tag, '7', 30_000, 1);
        sched.set_policy(Policy::Slo);
        sched.set_bypass_limit(limit);
        sched.submit(req_class(0, "a=1;?a=", 30, Priority::Interactive));
        sched.tick().unwrap(); // id 0 live
        sched.submit(req_class(1, "b=2;?b=", 50, Priority::Interactive));
        for id in 2..6u64 {
            sched.submit(req_class(id, "c=3;?c=", 2, Priority::Batch));
        }
        let done = sched.run_to_completion().unwrap();
        assert_eq!(done.len(), 6);
        for c in &done {
            assert!(c.error.is_none(), "req {}: {:?}", c.id, c.error);
        }
        let order: Vec<u64> = done.iter().map(|c| c.id).collect();
        (order, sched.metrics.bypass_admissions)
    };

    let (order, bypasses) = run("bypass2", 2);
    assert_eq!(bypasses, 2, "exactly the bypass limit may pass the parked head");
    assert_eq!(
        order,
        vec![2, 3, 0, 1, 4, 5],
        "two smalls bypass, then the head runs before the remaining smalls"
    );

    let (order0, bypasses0) = run("bypass0", 0);
    assert_eq!(bypasses0, 0);
    assert_eq!(
        order0,
        vec![0, 1, 2, 3, 4, 5],
        "with bypass disabled nothing passes the parked head"
    );
}

#[test]
fn live_deadline_expires_to_terminal_state_and_releases_reservation() {
    let mut sched = fake_scheduler("deadline_live", '7', 1 << 30, 1);
    let mut r = req(1, "a=1;?a=", 50);
    r.deadline_us = Some(10_000);
    sched.submit(r);
    sched.tick().unwrap(); // admitted, decoding
    assert!(sched.pool.used_bytes() > 0, "live sequence must hold a reservation");
    sched.set_now(10_000);
    sched.tick().unwrap();
    assert_eq!(
        sched.pool.used_bytes(),
        0,
        "expiry must release the cache reservation"
    );
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert!(done[0].error.as_deref().unwrap_or("").contains("deadline"));
    assert_eq!(done[0].n_generated, 0);
    assert_eq!(sched.metrics.expired, 1);
}

#[test]
fn queued_deadline_expires_without_blocking_the_live_sequence() {
    let mut sched = fake_scheduler("deadline_queued", '7', 6000, 1);
    sched.submit(req(1, "a=1;?a=", 2)); // fills the budget
    let mut r = req(2, "b=2;?b=", 2);
    r.deadline_us = Some(1_000);
    sched.submit(r);
    sched.tick().unwrap(); // 1 live, 2 parked
    sched.set_now(2_000);
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);
    let expired = done.iter().find(|c| c.id == 2).unwrap();
    assert!(expired.error.as_deref().unwrap_or("").contains("deadline"));
    assert_eq!(expired.n_generated, 0);
    let ok = done.iter().find(|c| c.id == 1).unwrap();
    assert_eq!(ok.text, "77");
    assert!(ok.error.is_none());
    assert_eq!(sched.metrics.expired, 1);
    assert_eq!(sched.metrics.preemptions, 0);
}

#[test]
fn deadline_free_requests_never_expire() {
    let mut sched = fake_scheduler("deadline_none", '7', 1 << 30, 1);
    sched.submit(req(1, "a=1;?a=", 3));
    sched.set_now(u64::MAX / 2);
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert!(done[0].error.is_none());
    assert_eq!(sched.metrics.expired, 0);
}

#[test]
fn server_answers_malformed_requests_and_serves_valid_ones() {
    let dir = write_fake_artifacts("server", '7');
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop_srv = stop.clone();
    let server = std::thread::spawn(move || {
        let manifest = Manifest::load(&dir).expect("fake manifest");
        let mut engine =
            Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
        engine.set_workers(2);
        let sched = Scheduler::new(engine, 1 << 30);
        serve(sched, "127.0.0.1:0", stop_srv, move |a| {
            let _ = addr_tx.send(a);
        })
    });
    let addr = addr_rx.recv().expect("server bound");
    let mut client = Client::connect(addr).expect("connect");

    // Malformed JSON: used to be silently dropped (client hung forever).
    let resp = client.send_line("this is not json").expect("error response");
    assert!(resp.get("error").as_str().unwrap_or("").contains("JSON"));

    // Parseable but missing the prompt field.
    let resp = client.send_line(r#"{"max_new_tokens": 3}"#).expect("error response");
    assert!(resp.get("error").as_str().unwrap_or("").contains("prompt"));

    // Unencodable prompt: fails through the scheduler, with the error
    // reported in-band on the completion line.
    let resp = client.send_line(r#"{"prompt": "Z!", "max_new_tokens": 3}"#).unwrap();
    assert!(resp.get("error").as_str().is_some());

    // A valid request still completes on the same connection.
    let resp = client.generate("a=15;?a=", 3).expect("completion");
    assert_eq!(resp.get("text").as_str(), Some("777"));
    assert_eq!(resp.get("n_generated").as_f64(), Some(3.0));
    assert_eq!(resp.get("error").as_str(), None);

    // SLO fields ride along in the request JSON: a labeled request with a
    // generous deadline completes normally...
    let resp = client
        .generate_with("b=22;?b=", 2, innerq::coordinator::Priority::Interactive, Some(60_000.0))
        .expect("completion");
    assert_eq!(resp.get("text").as_str(), Some("77"));
    assert_eq!(resp.get("error").as_str(), None);

    // ... and an unknown priority class is answered in-band instead of
    // silently running at the wrong priority.
    let resp = client
        .send_line(r#"{"prompt": "a=1;?a=", "priority": "warp"}"#)
        .expect("error response");
    assert!(resp.get("error").as_str().unwrap_or("").contains("priority"));

    stop.store(true, Ordering::Relaxed);
    let _ = std::net::TcpStream::connect(addr); // poke the acceptor awake
    server.join().expect("server thread").expect("serve result");
}

// ---------------------------------------------------------------------------
// Admin/metrics plane: the second listener must expose live counters in the
// documented text format, move them monotonically under load, and stay
// strictly read-only — no admin command, valid or garbage, may perturb the
// data plane.
// ---------------------------------------------------------------------------

fn start_admin_server(
    tag: &str,
) -> (
    Arc<AtomicBool>,
    innerq::server::Bound,
    std::thread::JoinHandle<anyhow::Result<()>>,
) {
    let dir = write_fake_artifacts(tag, '7');
    let stop = Arc::new(AtomicBool::new(false));
    let stop_srv = stop.clone();
    let (bound_tx, bound_rx) = mpsc::channel();
    let server = std::thread::spawn(move || {
        let manifest = Manifest::load(&dir).expect("fake manifest");
        let mut engine = Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
        engine.set_workers(2);
        let sched = Scheduler::new(engine, 1 << 30);
        let cfg = ServerConfig { io_workers: 2, admin_addr: Some("127.0.0.1:0".into()) };
        serve_with(sched, "127.0.0.1:0", cfg, stop_srv, move |b| {
            let _ = bound_tx.send(b);
        })
    });
    let bound = bound_rx.recv().expect("server bound");
    (stop, bound, server)
}

fn stat(stats: &[(String, u64)], name: &str) -> u64 {
    stats
        .iter()
        .find(|(n, _)| n == name)
        .unwrap_or_else(|| panic!("stat '{name}' missing from admin snapshot"))
        .1
}

#[test]
fn admin_stats_parse_and_counters_move_monotonically_under_load() {
    let (stop, bound, server) = start_admin_server("admin_stats");
    let admin_addr = bound.admin.expect("admin plane enabled");
    let mut admin = AdminClient::connect(admin_addr).expect("admin connect");

    // Golden format: `version` names the crate version, `stats` parses into
    // ordered (name, value) pairs carrying the documented counter set.
    let version = admin.command("version").expect("version");
    assert_eq!(version, format!("VERSION {}", env!("CARGO_PKG_VERSION")));
    let before = admin.stats().expect("stats");
    for name in [
        "uptime_us",
        "pending",
        "decode_steps",
        "cancelled",
        "pool_used_bytes",
        "tier_residents",
        "prefix_pins",
        "ttft_count",
        "e2e_p99_us",
    ] {
        let _ = stat(&before, name); // panics if missing
    }
    assert_eq!(stat(&before, "e2e_count"), 0, "no completions yet");

    // Load: a few completed requests must move the monotonic counters and
    // leave the gauges drained.
    let mut client = Client::connect(bound.data).expect("connect");
    for _ in 0..3 {
        let resp = client.generate("a=15;?a=", 2).expect("completion");
        assert_eq!(resp.get("text").as_str(), Some("77"));
    }
    // The driver refreshes the snapshot once per loop; give it a beat.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    let after = loop {
        let s = admin.stats().expect("stats");
        if stat(&s, "e2e_count") >= 3 {
            break s;
        }
        assert!(std::time::Instant::now() < deadline, "snapshot never caught up: {s:?}");
        std::thread::sleep(std::time::Duration::from_millis(5));
    };
    assert!(stat(&after, "decode_steps") > stat(&before, "decode_steps"));
    assert!(stat(&after, "prefill_tokens") > stat(&before, "prefill_tokens"));
    assert!(stat(&after, "uptime_us") > stat(&before, "uptime_us"));
    assert_eq!(stat(&after, "ttft_count"), 3);
    assert_eq!(stat(&after, "pool_used_bytes"), 0, "nothing live after completion");
    assert_eq!(stat(&after, "pending"), 0);

    // Monotonic counters never move backwards between snapshots.
    let again = admin.stats().expect("stats");
    for name in ["decode_steps", "prefill_tokens", "e2e_count", "cancelled", "rejected"] {
        assert!(
            stat(&again, name) >= stat(&after, name),
            "{name} moved backwards"
        );
    }

    stop.store(true, Ordering::Relaxed);
    server.join().expect("server thread").expect("serve result");
}

#[test]
fn admin_garbage_and_quit_never_touch_the_data_plane() {
    let (stop, bound, server) = start_admin_server("admin_garbage");
    let admin_addr = bound.admin.expect("admin plane enabled");

    // Garbage commands are answered with ERROR lines, in order, and the
    // connection stays usable.
    let mut admin = AdminClient::connect(admin_addr).expect("admin connect");
    let resp = admin.command("bogus").expect("error reply");
    assert_eq!(resp, "ERROR unknown command 'bogus'");
    let resp = admin.command("stats extra-arg").expect("error reply");
    assert!(resp.starts_with("ERROR unknown command"));
    let resp = admin.command("version").expect("still serving");
    assert!(resp.starts_with("VERSION "));

    // `quit` closes only this admin connection; a fresh one still serves.
    assert!(admin.command("quit").is_err(), "quit must close the connection");
    let mut admin2 = AdminClient::connect(admin_addr).expect("admin reconnect");
    let stats = admin2.stats().expect("stats after quit");
    assert!(stat(&stats, "uptime_us") > 0);

    // Through all of the above the data plane never noticed: a request
    // completes exactly, and the abuse left no counters behind.
    let mut client = Client::connect(bound.data).expect("connect");
    let resp = client.generate("b=22;?b=", 3).expect("completion");
    assert_eq!(resp.get("text").as_str(), Some("777"));
    assert_eq!(resp.get("error").as_str(), None);
    let stats = admin2.stats().expect("stats");
    assert_eq!(stat(&stats, "rejected"), 0);
    assert_eq!(stat(&stats, "cancelled"), 0);

    stop.store(true, Ordering::Relaxed);
    server.join().expect("server thread").expect("serve result");
}
