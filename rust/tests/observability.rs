//! Observability-plane tests: per-request lifecycle spans across every
//! terminal state, Chrome-trace schema + replay-report reconciliation,
//! the Prometheus page over a real admin socket, the admin `trace`
//! window, and the zero-perturbation contract (tracing must not change
//! a single output byte at any worker count).
//!
//! Tracing is process-global (one tracer count, one lane table), and
//! cargo runs each test *file* as its own process — so only this file
//! arms tracing, and the tests below serialize themselves on [`GATE`]
//! so concurrently running tests in this binary cannot drain each
//! other's span events out of the shared lane rings.

use innerq::coordinator::{Engine, Policy, Preemption, Priority, Request, Scheduler};
use innerq::obs::recorder::Recorder;
use innerq::obs::{self, SpanKind};
use innerq::runtime::Manifest;
use innerq::server::{serve_with, AdminClient, Client, ServerConfig};
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::util::json::Json;
use innerq::workload::replay::{replay, CostModel, Outcome};
use innerq::workload::trace::{generate_timed, Arrival, TimedRequest, TimedTraceConfig};
use innerq::QuantMethod;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};

/// Serializes every test that arms tracing or drains the global rings.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Pull any straggler events a previous test left in the lane rings into
/// a throwaway recorder, so this test starts from clean rings.
fn flush_stale_events() {
    let mut scratch = Recorder::new();
    scratch.drain();
}

fn fake_scheduler(tag: &str, budget: usize, workers: usize, policy: Policy) -> Scheduler {
    let dir = write_fake_artifacts(tag, '7');
    let manifest = Manifest::load(&dir).expect("fake manifest");
    let mut engine = Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
    engine.set_workers(workers);
    let mut sched = Scheduler::new(engine, budget);
    sched.set_policy(policy);
    sched
}

fn req(id: u64, prompt: &str, max_new_tokens: usize) -> Request {
    Request::new(id, prompt, max_new_tokens)
}

fn req_class(id: u64, prompt: &str, max_new_tokens: usize, p: Priority) -> Request {
    let mut r = Request::new(id, prompt, max_new_tokens);
    r.priority = p;
    r
}

// ---------------------------------------------------------------------------
// Lifecycle matrix: every terminal state leaves a Request span with the
// right tag, and the stage/cache spans around it actually fire.
// ---------------------------------------------------------------------------

#[test]
fn request_lifecycle_spans_cover_every_terminal_state() {
    let _g = gate();
    flush_stale_events();
    let _guard = obs::TraceGuard::arm();

    // Budget fits one est-4608 sequence; offload preemption so the
    // snapshot/restore and warm-tier spans fire too.
    let mut sched = fake_scheduler("obs_lifecycle", 6000, 2, Policy::Slo);
    sched.set_preemption(Preemption::Offload);
    sched.set_warm_budget(1 << 20);

    // ok + offload/restore: batch goes live, interactive preempts it into
    // the warm tier, both complete.
    sched.submit(req_class(1, "a=1;?a=", 2, Priority::Batch));
    sched.tick().expect("tick");
    sched.submit(req_class(2, "b=2;?b=", 2, Priority::Interactive));
    let done = sched.run_to_completion().expect("run");
    assert_eq!(done.len(), 2);
    assert!(sched.metrics.offloads >= 1, "offload must have happened");

    // rejected: estimate far over the cache budget.
    sched.submit(req(3, "a=1;?a=", 200));
    // expired: the deadline passes while still queued.
    let mut doomed = req(4, "b=2;?b=", 2);
    doomed.deadline_us = Some(sched.now_us() + 1);
    sched.submit(doomed);
    sched.set_now(sched.now_us() + 10_000);
    // cancelled: admitted live, then cancelled before it can finish.
    sched.submit(req(5, "c=3;?c=", 4));
    sched.tick().expect("tick");
    assert!(sched.cancel(5), "id 5 must be live to cancel");
    let _ = sched.run_to_completion().expect("run");

    let mut rec = sched.obs.lock().unwrap_or_else(|e| e.into_inner());
    rec.drain();

    let terminal: BTreeMap<u64, &'static str> = rec
        .events()
        .filter(|e| e.kind == SpanKind::Request)
        .map(|e| (e.id, e.tag.expect("request span needs a terminal tag")))
        .collect();
    assert_eq!(terminal.get(&1), Some(&"ok"), "spans: {terminal:?}");
    assert_eq!(terminal.get(&2), Some(&"ok"));
    assert_eq!(terminal.get(&3), Some(&"rejected"));
    assert_eq!(terminal.get(&4), Some(&"expired"));
    assert_eq!(terminal.get(&5), Some(&"cancelled"));

    // Stage coverage: the driver stages, the fused attention jobs (overlap
    // is the default pipeline), and the offload path's cache spans.
    let kinds: BTreeSet<SpanKind> = rec.events().map(|e| e.kind).collect();
    for kind in [
        SpanKind::Queued,
        SpanKind::Prefill,
        SpanKind::DecodeStep,
        SpanKind::Request,
        SpanKind::StageQkv,
        SpanKind::StageOut,
        SpanKind::StageHead,
        SpanKind::AttnJob,
        SpanKind::Snapshot,
        SpanKind::Restore,
        SpanKind::TierInsert,
        SpanKind::TierTake,
    ] {
        assert!(kinds.contains(&kind), "no {kind:?} span recorded; got {kinds:?}");
    }

    // AttnJob spans carry the active ISA arm as their tag.
    let isa = innerq::kernels::dispatch::active().name();
    assert!(
        rec.events()
            .filter(|e| e.kind == SpanKind::AttnJob)
            .all(|e| e.tag == Some(isa)),
        "attn jobs must be tagged with the active ISA arm {isa:?}"
    );

    // Span sanity: durations are finite and every request span's window
    // covers its decode steps' emission order (start before end).
    for e in rec.events() {
        assert!(e.dur_us < 120_000_000, "absurd duration in {e:?}");
    }
}

// ---------------------------------------------------------------------------
// Chrome-trace schema + reconciliation with the replay report.
// ---------------------------------------------------------------------------

fn stress_trace(n: usize) -> Vec<TimedRequest> {
    generate_timed(&TimedTraceConfig {
        n_requests: n,
        arrival: Arrival::Poisson { rate_rps: 800.0 },
        priority_mix: [1.0, 2.0, 1.0],
        deadlines_us: [Some(200_000), None, None],
        seed: 42,
        ..TimedTraceConfig::default()
    })
}

#[test]
fn chrome_trace_reconciles_exactly_with_the_replay_report() {
    let _g = gate();
    flush_stale_events();
    let guard = obs::TraceGuard::arm();

    let trace = stress_trace(32);
    let mut sched = fake_scheduler("obs_reconcile", 64_000, 2, Policy::Slo);
    let report = replay(&mut sched, &trace, &CostModel::default()).expect("replay");

    let doc = {
        let mut rec = sched.obs.lock().unwrap_or_else(|e| e.into_inner());
        rec.drain();
        rec.chrome_trace(None)
    };
    drop(guard);

    // Schema: the document round-trips through the parser and every event
    // carries the complete-span shape with a known name and category.
    let parsed = Json::parse(&doc.dump()).expect("trace JSON parses");
    assert_eq!(parsed.get("displayTimeUnit").as_str(), Some("ms"));
    let events = parsed.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(!events.is_empty());
    let names: BTreeSet<&str> = SpanKind::ALL.iter().map(|k| k.name()).collect();
    let cats: BTreeSet<&str> = SpanKind::ALL.iter().map(|k| k.cat()).collect();
    for e in events {
        assert_eq!(e.get("ph").as_str(), Some("X"));
        assert_eq!(e.get("pid").as_f64(), Some(1.0));
        assert!(e.get("tid").as_f64().is_some());
        assert!(e.get("ts").as_f64().unwrap() >= 0.0);
        assert!(e.get("dur").as_f64().unwrap() >= 0.0);
        assert!(names.contains(e.get("name").as_str().expect("name")));
        assert!(cats.contains(e.get("cat").as_str().expect("cat")));
        assert!(e.get("args").get("id").as_f64().is_some());
    }

    // Reconciliation: the trace's request spans are exactly the replay
    // report's request set — same ids, matching terminal states.
    let spans: BTreeMap<u64, String> = events
        .iter()
        .filter(|e| e.get("name").as_str() == Some("request"))
        .map(|e| {
            (
                e.get("args").get("id").as_f64().expect("id") as u64,
                e.get("args").get("tag").as_str().expect("terminal tag").to_string(),
            )
        })
        .collect();
    let report_ids: BTreeSet<u64> = report.records.iter().map(|r| r.id).collect();
    assert_eq!(
        spans.keys().copied().collect::<BTreeSet<u64>>(),
        report_ids,
        "request spans must cover the replay request set exactly"
    );
    for r in &report.records {
        let want = match r.outcome.expect("terminal outcome") {
            Outcome::Ok => "ok",
            Outcome::Rejected => "rejected",
            Outcome::Expired => "expired",
        };
        assert_eq!(
            spans.get(&r.id).map(String::as_str),
            Some(want),
            "request {} terminal state disagrees with the replay report",
            r.id
        );
    }
    // The stress trace must actually exercise more than the happy path.
    assert!(report.count(Outcome::Ok) > 0);
    assert!(
        report.count(Outcome::Rejected) + report.count(Outcome::Expired) > 0,
        "stress trace produced no non-ok terminals; tighten it"
    );
}

// ---------------------------------------------------------------------------
// Zero-perturbation: tracing must not change a single output byte.
// ---------------------------------------------------------------------------

#[test]
fn tracing_never_changes_decode_output_bytes() {
    let _g = gate();
    let prompts = ["a=41;?a=", "b=07;c=22;?c=", "d=99;?d=", "e=15;f=33;?f="];
    let run = |tag: &str, workers: usize, traced: bool| -> Vec<(u64, String, usize)> {
        flush_stale_events();
        let _guard = traced.then(obs::TraceGuard::arm);
        let mut sched = fake_scheduler(tag, 1 << 30, workers, Policy::Fifo);
        for (i, p) in prompts.iter().enumerate() {
            sched.submit(req(i as u64, p, 4));
        }
        let mut done = sched.run_to_completion().expect("run");
        done.sort_by_key(|c| c.id);
        done.into_iter().map(|c| (c.id, c.text, c.n_generated)).collect()
    };

    let reference = run("obs_id_ref", 1, false);
    for workers in [1usize, 2, 4] {
        let plain = run(&format!("obs_id_w{workers}"), workers, false);
        let traced = run(&format!("obs_id_w{workers}_t"), workers, true);
        assert_eq!(plain, reference, "workers={workers}: untraced diverged");
        assert_eq!(
            traced, reference,
            "workers={workers}: tracing changed the output bytes"
        );
    }
}

// ---------------------------------------------------------------------------
// Live server: Prometheus page + stats tail + admin trace window.
// ---------------------------------------------------------------------------

fn start_admin_server(
    tag: &str,
    io_workers: usize,
) -> (
    Arc<AtomicBool>,
    innerq::server::Bound,
    std::thread::JoinHandle<anyhow::Result<()>>,
) {
    let dir = write_fake_artifacts(tag, '7');
    let stop = Arc::new(AtomicBool::new(false));
    let stop_srv = stop.clone();
    let (bound_tx, bound_rx) = mpsc::channel();
    let server = std::thread::spawn(move || {
        let manifest = Manifest::load(&dir).expect("fake manifest");
        let mut engine = Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
        engine.set_workers(2);
        let sched = Scheduler::new(engine, 1 << 30);
        let cfg = ServerConfig { io_workers, admin_addr: Some("127.0.0.1:0".into()) };
        serve_with(sched, "127.0.0.1:0", cfg, stop_srv, move |b| {
            let _ = bound_tx.send(b);
        })
    });
    let bound = bound_rx.recv().expect("server bound");
    (stop, bound, server)
}

fn stat(stats: &[(String, u64)], name: &str) -> u64 {
    stats
        .iter()
        .find(|(n, _)| n == name)
        .unwrap_or_else(|| panic!("stat '{name}' missing from admin snapshot"))
        .1
}

#[test]
fn admin_metrics_page_is_well_formed_and_stats_tail_is_append_only() {
    let _g = gate();
    flush_stale_events();
    let (stop, bound, server) = start_admin_server("obs_metrics", 2);
    let admin_addr = bound.admin.expect("admin plane enabled");
    let mut admin = AdminClient::connect(admin_addr).expect("admin connect");

    let mut client = Client::connect(bound.data).expect("connect");
    for _ in 0..3 {
        let resp = client.generate("a=15;?a=", 2).expect("completion");
        assert_eq!(resp.get("text").as_str(), Some("77"));
    }
    // Wait for the snapshot to pick the completions up.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    let stats = loop {
        let s = admin.stats().expect("stats");
        if stat(&s, "e2e_count") >= 3 {
            break s;
        }
        assert!(std::time::Instant::now() < deadline, "snapshot never caught up");
        std::thread::sleep(std::time::Duration::from_millis(5));
    };

    // New stats ride strictly *after* the pre-existing tail (append-only
    // contract: old parsers index by prefix order).
    let names: Vec<&str> = stats.iter().map(|(n, _)| n.as_str()).collect();
    let pos =
        |n: &str| names.iter().position(|x| *x == n).unwrap_or_else(|| panic!("{n} missing"));
    assert!(pos("uptime_secs") > pos("e2e_max_us"));
    assert!(pos("io_conns_0") > pos("uptime_secs"));
    assert!(pos("io_conns_1") > pos("io_conns_0"));
    assert_eq!(names.last(), Some(&"stats_generation"));
    assert!(stat(&stats, "stats_generation") > 0);
    // One connection is live right now; the per-worker gauges must see it.
    assert!(stat(&stats, "io_conns_0") + stat(&stats, "io_conns_1") >= 1);

    // The generation is monotonic across snapshots.
    let again = admin.stats().expect("stats");
    assert!(stat(&again, "stats_generation") >= stat(&stats, "stats_generation"));

    // Prometheus page: every stats field appears in the innerq_ namespace,
    // typed; the tracing meta-series report the disabled state.
    let page = admin.metrics().expect("metrics");
    for required in [
        "# TYPE innerq_decode_steps gauge",
        "# TYPE innerq_uptime_secs gauge",
        "# TYPE innerq_io_conns_0 gauge",
        "# TYPE innerq_stats_generation gauge",
        "innerq_trace_enabled 0",
    ] {
        assert!(page.contains(required), "metrics page missing {required:?}:\n{page}");
    }
    // Exposition lint: every line is a well-formed comment or sample.
    for line in page.lines() {
        assert!(!line.trim().is_empty(), "blank line in exposition");
        if let Some(rest) = line.strip_prefix("# ") {
            let mut parts = rest.splitn(3, ' ');
            assert!(matches!(parts.next(), Some("HELP") | Some("TYPE")), "bad comment {line:?}");
            assert!(parts.next().unwrap().starts_with("innerq_"), "bad family in {line:?}");
        } else {
            let (series, value) = line.rsplit_once(' ').expect("sample needs a value");
            assert!(series.starts_with("innerq_"), "series outside namespace: {line:?}");
            assert!(value.parse::<f64>().is_ok(), "non-numeric value in {line:?}");
        }
    }

    stop.store(true, Ordering::Relaxed);
    drop(client);
    server.join().expect("server thread").expect("serve result");
}

#[test]
fn admin_trace_window_produces_chrome_json_on_a_live_server() {
    let _g = gate();
    flush_stale_events();
    let (stop, bound, server) = start_admin_server("obs_trace_cmd", 2);
    let admin_addr = bound.admin.expect("admin plane enabled");
    let mut admin = AdminClient::connect(admin_addr).expect("admin connect");

    // Malformed windows are rejected in-band, before any tracing starts.
    for bad in ["trace 0", "trace 61", "trace abc", "trace "] {
        let resp = admin.command(bad).expect("error reply");
        assert!(resp.starts_with("ERROR"), "{bad:?} got {resp:?}");
    }

    // Keep the data plane busy for the whole trace window.
    let busy = Arc::new(AtomicBool::new(true));
    let busy_c = busy.clone();
    let data_addr = bound.data;
    let driver = std::thread::spawn(move || {
        let mut client = Client::connect(data_addr).expect("connect");
        let mut ok = 0u64;
        while busy_c.load(Ordering::Relaxed) {
            let resp = client.generate("a=15;?a=", 2).expect("completion");
            assert_eq!(resp.get("text").as_str(), Some("77"));
            ok += 1;
        }
        ok
    });

    // The trace command blocks for the window, then replies one JSON line.
    let reply = admin.command("trace 1").expect("trace reply");
    busy.store(false, Ordering::Relaxed);
    let completed = driver.join().expect("driver thread");
    assert!(completed > 0, "no requests completed during the window");

    let parsed = Json::parse(&reply).expect("trace reply must be JSON");
    let events = parsed.get("traceEvents").as_arr().expect("traceEvents");
    assert!(!events.is_empty(), "a busy 1s window must capture spans");
    let names: BTreeSet<&str> = events
        .iter()
        .map(|e| e.get("name").as_str().expect("name"))
        .collect();
    for required in ["request", "prefill", "decode_step", "ingress", "egress"] {
        assert!(names.contains(required), "window missing {required} spans: {names:?}");
    }
    assert!(
        events
            .iter()
            .filter(|e| e.get("name").as_str() == Some("request"))
            .all(|e| e.get("args").get("tag").as_str() == Some("ok")),
        "every request in this workload completes ok"
    );

    // The window is over: tracing must be disarmed again.
    let page = admin.metrics().expect("metrics");
    assert!(page.contains("innerq_trace_enabled 0"), "tracer leaked past the window");

    stop.store(true, Ordering::Relaxed);
    server.join().expect("server thread").expect("serve result");
}
