//! Cross-layer integration tests: Rust (L3) against the artifacts and golden
//! vectors produced by the Python build path (L2/L1).
//!
//! These tests need `make artifacts` to have run; they skip (with a note)
//! when the manifest is missing so `cargo test` stays green pre-build.

use innerq::coordinator::Engine;
use innerq::quant::group::{quantize, Mode};
use innerq::quant::QuantMethod;
use innerq::runtime::executable::{In, Stage};
use innerq::runtime::Manifest;
use innerq::util::json::Json;

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("[skip] artifacts/ not built; run `make artifacts`");
            None
        }
    }
}

fn load_json(path: &str) -> Json {
    let text = std::fs::read_to_string(path).unwrap();
    Json::parse(&text).unwrap()
}

/// The Rust quantizer must agree with the Python reference bit-for-bit:
/// identical codes, f16-identical scales/zeros, identical hybrid mask.
#[test]
fn quantizer_parity_with_python_reference() {
    if manifest().is_none() {
        return;
    }
    let g = load_json("artifacts/golden/quantizer.json");
    let mat = g.get("matrix").as_f32_vec().unwrap();
    let d_h = 64usize;
    assert_eq!(mat.len(), 64 * d_h);
    for case in g.get("cases").as_arr().unwrap() {
        let bits = case.get("bits").as_usize().unwrap() as u8;
        let mode = match case.get("mode").as_str().unwrap() {
            "sym" => Mode::Sym,
            "asym" => Mode::Asym,
            _ => Mode::Hybrid,
        };
        let want_codes = case.get("codes").as_f32_vec().unwrap();
        let want_scale = case.get("scale").as_f32_vec().unwrap();
        let want_zero = case.get("zero").as_f32_vec().unwrap();
        let want_mask = case.get("mask").as_f32_vec().unwrap();

        let mut gi = 0usize;
        let mut mismatched_codes = 0usize;
        for row in mat.chunks_exact(d_h) {
            for group in row.chunks_exact(32) {
                let mut raw = [0u8; 32];
                let p = quantize(mode, group, bits, &mut raw);
                // scale magnitude parity (f16-exact)
                let scale = p.scale_f32();
                assert!(
                    (scale - want_scale[gi]).abs() < 1e-6 * scale.abs().max(1e-3),
                    "{mode:?} b{bits} group {gi}: scale {scale} vs {}",
                    want_scale[gi]
                );
                // mask parity
                assert_eq!(
                    p.is_asym(),
                    want_mask[gi] != 0.0,
                    "{mode:?} b{bits} group {gi} mask"
                );
                if p.is_asym() {
                    assert!(
                        (p.zero_f32() - want_zero[gi]).abs() < 1e-6,
                        "group {gi} zero"
                    );
                }
                // code parity: python stores signed symmetric codes, rust
                // stores biased raw codes. Allow <=1 ULP-of-rounding flips.
                let bias = if p.is_asym() { 0 } else { (1 << (bits - 1)) - 1 };
                for (i, &r) in raw.iter().enumerate() {
                    let rust_code = r as i32 - bias;
                    let py_code = want_codes[gi * 32 + i] as i32;
                    if (rust_code - py_code).abs() > 0 {
                        mismatched_codes += 1;
                        assert!(
                            (rust_code - py_code).abs() <= 1,
                            "group {gi} elem {i}: {rust_code} vs {py_code}"
                        );
                    }
                }
                gi += 1;
            }
        }
        // rounding-tie flips must be rare (<0.5%)
        assert!(
            (mismatched_codes as f64) < 0.005 * (mat.len() as f64),
            "{mode:?} b{bits}: {mismatched_codes} code mismatches"
        );
    }
}

/// Each decode stage executable must reproduce the Python-side outputs.
#[test]
fn stage_golden_vectors() {
    let Some(m) = manifest() else { return };
    let g = load_json("artifacts/golden/stages.json");
    let close = |a: &[f32], b: &[f32], tol: f32, what: &str| {
        assert_eq!(a.len(), b.len(), "{what} length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < tol, "{what}[{i}]: {x} vs {y}");
        }
    };

    let token = g.get("token").as_f64().unwrap() as i32;
    let h_want = g.get("h").as_f32_vec().unwrap();
    let embed = Stage::load("embed", &m.path("embed_b1").unwrap()).unwrap();
    let h = embed.run(&[In::I32(&[token], &[1])]).unwrap().f32(0).unwrap();
    close(&h, &h_want, 1e-4, "embed");

    let qkv = Stage::load("qkv", &m.path("qkv_l0_b1").unwrap()).unwrap();
    let out = qkv
        .run(&[In::F32(&h, &[1, m.model.d_model as i64]), In::I32(&[0], &[1])])
        .unwrap();
    close(&out.f32(0).unwrap(), &g.get("q").as_f32_vec().unwrap(), 1e-3, "q");
    close(&out.f32(1).unwrap(), &g.get("k").as_f32_vec().unwrap(), 1e-3, "k");
    close(&out.f32(2).unwrap(), &g.get("v").as_f32_vec().unwrap(), 1e-3, "v");

    let ctx = g.get("ctx").as_f32_vec().unwrap();
    let h2_want = g.get("h2").as_f32_vec().unwrap();
    let outl = Stage::load("out", &m.path("out_l0_b1").unwrap()).unwrap();
    let h2 = outl
        .run(&[
            In::F32(&h, &[1, m.model.d_model as i64]),
            In::F32(&ctx, &[1, m.model.q_dim() as i64]),
        ])
        .unwrap()
        .f32(0)
        .unwrap();
    close(&h2, &h2_want, 1e-3, "out");

    let head = Stage::load("head", &m.path("head_b1").unwrap()).unwrap();
    let logits = head
        .run(&[In::F32(&h2, &[1, m.model.d_model as i64])])
        .unwrap()
        .f32(0)
        .unwrap();
    close(&logits, &g.get("head").as_f32_vec().unwrap(), 1e-3, "head");
}

/// The full Rust decode loop (FP16 cache) must reproduce the Python staged
/// decode trace logits step by step.
#[test]
fn fp_decode_matches_python_trace() {
    let Some(m) = manifest() else { return };
    let g = load_json("artifacts/golden/decode_fp.json");
    let tokens: Vec<i32> =
        g.get("tokens").as_f32_vec().unwrap().iter().map(|&t| t as i32).collect();
    let logits_rows = g.get("logits").as_arr().unwrap();

    let engine = Engine::new(m, QuantMethod::BaselineFp16.config()).unwrap();
    let mut seq = engine.start_empty();
    for (t, want_row) in tokens.iter().zip(logits_rows) {
        engine.decode_step(&mut [&mut seq], &[*t]).unwrap();
        let want = want_row.as_f32_vec().unwrap();
        let got = &seq.last_logits;
        let err = innerq::util::stats::max_abs_diff(got, &want);
        assert!(err < 5e-3, "step logits diverged: {err}");
    }
}

/// Prefill and step-by-step decode must agree (FP path): same final logits.
#[test]
fn prefill_equals_stepwise_decode() {
    let Some(m) = manifest() else { return };
    let engine = Engine::new(m.clone(), QuantMethod::BaselineFp16.config()).unwrap();
    let prompt = {
        let mut t = vec![m.bos];
        t.extend(m.encode("a=41;b=07;c=93;?b=").unwrap());
        t
    };
    let seq_prefill = engine.prefill(&prompt).unwrap();
    let mut seq_step = engine.start_empty();
    for t in &prompt {
        engine.decode_step(&mut [&mut seq_step], &[*t]).unwrap();
    }
    let err = innerq::util::stats::max_abs_diff(&seq_prefill.last_logits, &seq_step.last_logits);
    assert!(err < 5e-3, "prefill vs stepwise logits: {err}");
    assert_eq!(seq_prefill.len(), seq_step.len());
}

/// The Pallas-lowered quantized-attention artifact (L1 inside L2) must agree
/// with the Rust native InnerQ attention on the same quantized cache.
#[test]
fn pallas_quant_attention_matches_rust() {
    let Some(m) = manifest() else { return };
    let n = m.quant_attn_tokens;
    let d_h = m.model.d_h;
    let ng = d_h / 32;
    let mut rng = innerq::util::rng::Rng::new(77);

    // Build a random cache and quantize it with the Rust quantizer in the
    // exact layouts the artifact expects (signed sym codes as i32).
    let keys: Vec<f32> = (0..n * d_h).map(|_| rng.next_normal()).collect();
    let vals: Vec<f32> = (0..n * d_h).map(|_| rng.next_normal()).collect();
    let q: Vec<f32> = (0..d_h).map(|_| rng.next_normal()).collect();

    let bias = 3i32; // 3-bit symmetric
    let mut kcodes = vec![0i32; n * d_h];
    let mut kscale = vec![0f32; n * ng];
    let mut raw = [0u8; 32];
    for (t, row) in keys.chunks_exact(d_h).enumerate() {
        for (gi, group) in row.chunks_exact(32).enumerate() {
            let p = quantize(Mode::Sym, group, 3, &mut raw);
            kscale[t * ng + gi] = p.scale_f32();
            for i in 0..32 {
                kcodes[t * d_h + gi * 32 + i] = raw[i] as i32 - bias;
            }
        }
    }
    // value chunks: (n/32, d_h, 32) channel-major
    let chunks = n / 32;
    let mut vcodes = vec![0i32; n * d_h];
    let mut vscale = vec![0f32; chunks * d_h];
    let mut col = [0f32; 32];
    for c in 0..chunks {
        for ch in 0..d_h {
            for t in 0..32 {
                col[t] = vals[(c * 32 + t) * d_h + ch];
            }
            let p = quantize(Mode::Sym, &col, 3, &mut raw);
            vscale[c * d_h + ch] = p.scale_f32();
            for t in 0..32 {
                vcodes[(c * d_h + ch) * 32 + t] = raw[t] as i32 - bias;
            }
        }
    }

    let stage = Stage::load("quant_attn", &m.path("quant_attn").unwrap()).unwrap();
    let out = stage
        .run(&[
            In::F32(&q, &[d_h as i64]),
            In::I32(&kcodes, &[n as i64, ng as i64, 32]),
            In::F32(&kscale, &[n as i64, ng as i64]),
            In::I32(&vcodes, &[chunks as i64, d_h as i64, 32]),
            In::F32(&vscale, &[chunks as i64, d_h as i64]),
        ])
        .unwrap();
    let pallas_ctx = out.f32(0).unwrap();

    // Rust native: same quantized cache via a window-less InnerQ config.
    let mut cfg = QuantMethod::InnerQBase.config();
    cfg.w_sink = 0;
    cfg.w_recent = 0;
    cfg.key_norm = false;
    let mut hc = innerq::cache::HeadCache::new(cfg, d_h);
    for (k, v) in keys.chunks_exact(d_h).zip(vals.chunks_exact(d_h)) {
        hc.append(k, v);
    }
    assert_eq!(hc.qk.len(), n, "all tokens quantized");
    let mut ctx = vec![0f32; d_h];
    let mut scratch = Vec::new();
    hc.attend(&q, &mut ctx, &mut scratch);

    let rel = innerq::util::stats::rel_l2(&pallas_ctx, &ctx);
    assert!(rel < 5e-3, "pallas vs rust quantized attention: rel {rel}");
}

/// End-to-end scheduler smoke: submit a few requests, run to completion.
#[test]
fn scheduler_serves_requests() {
    let Some(m) = manifest() else { return };
    let engine = Engine::new(m, QuantMethod::InnerQBase.config()).unwrap();
    let mut sched = innerq::coordinator::Scheduler::new(engine, 1 << 30);
    for (i, prompt) in ["a=41;b=07;?a=", "c=15;d=33;?d=", "e=99;?e="].iter().enumerate() {
        sched.submit(innerq::coordinator::Request::new(i as u64, *prompt, 6));
    }
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 3);
    for c in &done {
        assert!(c.n_generated > 0);
        assert!(c.ttft_us > 0);
    }
    assert!(sched.metrics.decode_steps > 0);
    assert!(sched.metrics.batched_seqs >= sched.metrics.decode_steps);
}
