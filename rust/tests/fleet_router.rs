//! Fleet router tests: exact round-robin placement, least-loaded load
//! spreading within per-replica budgets, affinity routing of readmits to
//! the snapshot-holding replica (including the migrate-under-load path,
//! whose byte-identity `Fleet::try_migrate` asserts on every copy), and
//! the fleet replay determinism matrix — full-report byte-identity across
//! worker counts, outcome byte-identity across replica counts.

use innerq::coordinator::{
    Affinity, Engine, Fleet, LeastLoaded, Policy, Preemption, Priority, Request, RoundRobin,
    Scheduler,
};
use innerq::runtime::Manifest;
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::workload::replay::{replay_fleet, CostModel, FleetReplayReport, Outcome};
use innerq::workload::trace::{Arrival, MultiTurnTraceConfig, TimedTraceConfig};
use innerq::QuantMethod;

fn fake_scheduler(dir_tag: &str, workers: usize, budget: usize) -> Scheduler {
    let dir = write_fake_artifacts(dir_tag, '7');
    let manifest = Manifest::load(&dir).expect("fake manifest");
    let mut engine = Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
    engine.set_workers(workers);
    let mut sched = Scheduler::new(engine, budget);
    sched.set_policy(Policy::Slo);
    sched.set_preemption(Preemption::Offload);
    sched.set_warm_budget(1 << 20);
    sched
}

fn fake_fleet(
    dir_tag: &str,
    n_replicas: usize,
    workers: usize,
    budget: usize,
    router: Box<dyn innerq::coordinator::RouterPolicy + Send>,
) -> Fleet {
    let replicas = (0..n_replicas).map(|_| fake_scheduler(dir_tag, workers, budget)).collect();
    Fleet::new(replicas, router)
}

fn req_class(id: u64, prompt: &str, max_new_tokens: usize, p: Priority) -> Request {
    let mut r = Request::new(id, prompt, max_new_tokens);
    r.priority = p;
    r
}

// ---------------------------------------------------------------------------
// placement
// ---------------------------------------------------------------------------

/// Round-robin is exact: submission `i` lands on replica `i % n`,
/// regardless of load.
#[test]
fn round_robin_placement_is_exact() {
    let mut fleet = fake_fleet("fleet_rr", 3, 1, 64_000, Box::new(RoundRobin::default()));
    for i in 0..7u64 {
        let dest = fleet.submit(Request::new(i, "a=1;?a=", 2));
        assert_eq!(dest, (i as usize) % 3, "submission {i}");
    }
    let done = fleet.run_to_completion().expect("fleet run");
    assert_eq!(done.len(), 7);
    for c in &done {
        assert_eq!(c.text, "77", "req {}", c.id);
        assert!(c.error.is_none());
    }
}

/// Least-loaded spreads a burst one request per replica, so a per-replica
/// budget that fits exactly one live sequence (6000 bytes at the fake
/// geometry) serves the whole burst with zero preemptions and zero
/// rejections — the same burst on one replica would thrash.
#[test]
fn least_loaded_spreads_a_burst_within_replica_budgets() {
    let mut fleet = fake_fleet("fleet_ll", 4, 1, 6000, Box::new(LeastLoaded));
    let mut dests = Vec::new();
    for i in 0..4u64 {
        dests.push(fleet.submit(Request::new(i, "a=1;?a=", 2)));
    }
    let mut sorted = dests.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, vec![0, 1, 2, 3], "burst must spread one per replica: {dests:?}");
    let done = fleet.run_to_completion().expect("fleet run");
    assert_eq!(done.len(), 4);
    for c in &done {
        assert_eq!(c.text, "77");
        assert!(c.error.is_none());
    }
    let m = fleet.aggregate_metrics();
    assert_eq!(m.rejected, 0);
    assert_eq!(m.preemptions, 0, "spread burst must not preempt anywhere");
}

// ---------------------------------------------------------------------------
// affinity and migration
// ---------------------------------------------------------------------------

/// Drive replica 1 into offloading request 10 (budget fits one sequence;
/// an interactive arrival preempts it into the warm tier).
fn offload_victim_on_replica_1(fleet: &mut Fleet) {
    let r1 = fleet.replica_mut(1);
    r1.submit(req_class(10, "a=1;?a=", 2, Priority::Batch));
    r1.tick().expect("tick"); // victim live
    r1.submit(req_class(11, "b=2;?b=", 2, Priority::Interactive));
    r1.tick().expect("tick"); // preempts + offloads 10
    assert!(fleet.replica(1).tier.contains(10), "victim must be warm-resident on replica 1");
}

/// Affinity routes a readmitted request to the replica already holding its
/// offload snapshot, even when another replica is idle.
#[test]
fn affinity_routes_readmit_to_snapshot_holder() {
    let mut fleet = fake_fleet("fleet_aff", 2, 1, 6000, Box::new(Affinity::default()));
    offload_victim_on_replica_1(&mut fleet);
    // Replica 0 is idle (pending 0) and replica 1 is loaded (pending 2),
    // but within the default headroom the snapshot holder still wins.
    let p = fleet.route(&req_class(10, "a=1;?a=", 2, Priority::Batch));
    assert_eq!(p.replica, 1, "readmit must follow the snapshot");
    assert_eq!(p.migrate_from, None);
    // A request with no locality anywhere falls back to least-loaded.
    let p = fleet.route(&Request::new(99, "c=3;?c=", 2));
    assert_eq!(p.replica, 0);
}

/// With zero headroom the loaded holder loses the placement and the router
/// migrates the snapshot to the least-loaded replica: a verbatim byte copy
/// between warm tiers (asserted inside `try_migrate` on every call), after
/// which the victim restores and completes on its new home.
#[test]
fn affinity_migrates_snapshot_when_holder_is_overloaded() {
    let mut fleet =
        fake_fleet("fleet_mig", 2, 1, 6000, Box::new(Affinity { migrate_headroom: 0 }));
    offload_victim_on_replica_1(&mut fleet);
    let bytes_on_src = fleet.replica(1).tier.resident_bytes();
    assert!(bytes_on_src > 0);

    let p = fleet.route(&req_class(10, "a=1;?a=", 2, Priority::Batch));
    assert_eq!(
        p,
        innerq::coordinator::Placement { replica: 0, migrate_from: Some(1) },
        "holder at pending 2 vs idle replica 0 must migrate at headroom 0"
    );
    assert!(fleet.try_migrate(10, 1, 0), "full-windows local snapshot must migrate");
    assert_eq!(fleet.migrations, 1);
    assert!(fleet.migrated_bytes > 0);
    assert_eq!(fleet.replica(1).tier.resident_bytes(), 0, "source tier must be emptied");
    assert!(fleet.replica(0).tier.contains(10), "snapshot must now live on replica 0");
    assert!(!fleet.replica(1).tier.contains(10), "and must be gone from replica 1");
    assert!(fleet.replica(0).holds_warm(10), "warm bookkeeping must move with the bytes");
    assert!(!fleet.replica(1).holds_warm(10));

    // The migrated victim restores and completes on replica 0; the
    // interactive request completes on replica 1; outputs are unchanged.
    let done = fleet.run_to_completion().expect("fleet run");
    assert_eq!(done.len(), 2);
    assert_eq!(done[0].id, 10);
    for c in &done {
        assert_eq!(c.text, "77", "req {}", c.id);
        assert!(c.error.is_none());
    }
    assert_eq!(fleet.replica(0).metrics.restores, 1, "new home must restore, not re-prefill");
    assert_eq!(fleet.replica(0).metrics.offload_lost, 0);
    assert_eq!(fleet.aggregate_metrics().restores, 1);
}

/// Migration refuses ids that are not (fully) offloaded on the claimed
/// source, and self- or out-of-range moves, leaving all state untouched.
#[test]
fn migration_refuses_non_resident_and_degenerate_moves() {
    let mut fleet = fake_fleet("fleet_mig_no", 2, 1, 6000, Box::new(Affinity::default()));
    assert!(!fleet.try_migrate(10, 0, 1), "nothing offloaded yet");
    offload_victim_on_replica_1(&mut fleet);
    assert!(!fleet.try_migrate(10, 1, 1), "self-migration is refused");
    assert!(!fleet.try_migrate(10, 1, 7), "out-of-range destination is refused");
    assert!(!fleet.try_migrate(77, 1, 0), "unknown id is refused");
    assert!(fleet.replica(1).tier.contains(10), "refusals must not disturb the resident");
    assert!(fleet.replica(1).holds_warm(10));
    assert_eq!(fleet.migrations, 0);
}

// ---------------------------------------------------------------------------
// fleet replay determinism matrix
// ---------------------------------------------------------------------------

fn fleet_replay(
    dir_tag: &str,
    router_name: &str,
    n_replicas: usize,
    workers: usize,
) -> FleetReplayReport {
    // Deadline-free greedy multi-turn trace; 5 sessions is coprime with
    // every replica count used here, so session→replica alignment cannot
    // accidentally make policies agree.
    let trace = innerq::workload::trace::generate_multi_turn(&MultiTurnTraceConfig {
        base: TimedTraceConfig {
            n_requests: 40,
            arrival: Arrival::Poisson { rate_rps: 2000.0 },
            seed: 2026,
            ..TimedTraceConfig::default()
        },
        n_sessions: 5,
        ..MultiTurnTraceConfig::default()
    });
    let router = innerq::coordinator::parse_router(router_name).expect("router name");
    let mut fleet = fake_fleet(dir_tag, n_replicas, workers, 64_000, router);
    replay_fleet(&mut fleet, &trace, &CostModel::default()).expect("fleet replay")
}

/// For a fixed (policy, replica count), the full fleet report — placement,
/// per-replica latencies, everything — is byte-identical across worker
/// counts: each replica's engine fan-out is byte-identical at any pool
/// size and the router never reads a wall clock.
#[test]
fn fleet_replay_is_byte_identical_across_worker_counts() {
    for policy in ["round-robin", "least-loaded", "affinity"] {
        let a = fleet_replay("fleet_det_w", policy, 2, 1);
        assert_eq!(a.n_requests(), 40);
        assert_eq!(a.completed(), 40, "{policy}: all requests must complete");
        let b = fleet_replay("fleet_det_w", policy, 2, 4);
        assert_eq!(
            a.to_json().dump(),
            b.to_json().dump(),
            "{policy}: fleet replay diverged between workers=1 and workers=4"
        );
    }
}

/// Across replica counts latency shifts (placement changes queueing), but
/// what each request *produces* cannot: the outcomes sub-report (terminal
/// outcome, completion text, token count, sorted by id) is byte-identical
/// for {1, 2, 4} replicas under every policy on a deadline-free greedy
/// trace with a comfortable per-replica budget.
#[test]
fn fleet_outcomes_are_byte_identical_across_replica_counts() {
    for policy in ["round-robin", "affinity"] {
        let one = fleet_replay("fleet_det_r", policy, 1, 1);
        assert_eq!(one.completed(), 40);
        assert_eq!(one.replicas[0].count(Outcome::Rejected), 0);
        let golden = one.outcomes_json().dump();
        for n in [2usize, 4] {
            let r = fleet_replay("fleet_det_r", policy, n, 1);
            assert_eq!(
                r.outcomes_json().dump(),
                golden,
                "{policy}: outcomes diverged between 1 and {n} replicas"
            );
        }
    }
}
