//! Disconnect-cancellation matrix: cancelling a request at every point in
//! its lifecycle — queued, live mid-decode, offloaded to the warm tier,
//! borrowing a shared prefix — must release every hold it has (cache-pool
//! reservation, warm-tier residency, prefix-store pin), emit a terminal
//! `Cancelled` event instead of a completion, and leave the freed budget
//! admissible to the next request. The socket-level test proves the full
//! wire path: a client that hangs up mid-stream is cancelled by the driver,
//! observed live through the admin plane.

use innerq::coordinator::{
    Engine, Policy, Preemption, Priority, Request, SchedEvent, Scheduler,
};
use innerq::runtime::Manifest;
use innerq::server::{serve_with, AdminClient, Client, ServerConfig};
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::QuantMethod;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn fake_scheduler(tag: &str, budget: usize) -> Scheduler {
    let dir = write_fake_artifacts(tag, '7');
    let manifest = Manifest::load(&dir).expect("fake manifest");
    let mut engine = Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
    engine.set_workers(1);
    Scheduler::new(engine, budget)
}

fn req(id: u64, prompt: &str, max_new_tokens: usize) -> Request {
    Request::new(id, prompt, max_new_tokens)
}

fn cancelled_ids(sched: &mut Scheduler) -> Vec<u64> {
    sched
        .take_events()
        .into_iter()
        .filter_map(|e| match e {
            SchedEvent::Cancelled { id } => Some(id),
            _ => None,
        })
        .collect()
}

#[test]
fn cancel_while_queued_removes_the_request_without_touching_the_pool() {
    // Budget fits one sequence: id 2 parks in the queue behind live id 1.
    let mut sched = fake_scheduler("cancel_queued", 6000);
    sched.record_events(true);
    sched.submit(req(1, "a=1;?a=", 2));
    sched.tick().unwrap(); // id 1 live
    sched.submit(req(2, "b=2;?b=", 2));
    let used_before = sched.pool.used_bytes();
    assert!(sched.cancel(2), "queued request must be cancellable");
    assert_eq!(
        sched.pool.used_bytes(),
        used_before,
        "a queued request holds no reservation to release"
    );
    assert_eq!(sched.metrics.cancelled, 1);
    assert_eq!(cancelled_ids(&mut sched), vec![2]);
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 1, "no completion may be emitted for a cancelled request");
    assert_eq!(done[0].id, 1);
    assert_eq!(done[0].text, "77");
    assert_eq!(sched.pool.used_bytes(), 0);
}

#[test]
fn cancel_mid_decode_releases_the_reservation_and_frees_the_budget() {
    // Budget fits exactly one sequence; id 1 decodes a long completion
    // (max_new 4 keeps it alive across the two ticks before the cancel).
    let mut sched = fake_scheduler("cancel_live", 6000);
    sched.record_events(true);
    sched.submit(req(1, "a=1;?a=", 4));
    sched.tick().unwrap(); // prefill
    sched.tick().unwrap(); // mid-decode
    assert!(sched.pool.used_bytes() > 0, "live sequence must hold a reservation");

    assert!(sched.cancel(1), "live request must be cancellable");
    assert_eq!(sched.pool.used_bytes(), 0, "cancel must release the reservation");
    assert_eq!(sched.metrics.cancelled, 1);
    assert_eq!(cancelled_ids(&mut sched), vec![1]);

    // The freed budget admits the next request immediately.
    sched.submit(req(2, "b=2;?b=", 2));
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, 2);
    assert_eq!(done[0].text, "77");
    assert!(done[0].error.is_none());
    assert_eq!(sched.metrics.cancelled, 1, "only the explicit cancel counts");
}

#[test]
fn cancel_while_offloaded_drops_the_warm_residency() {
    // SLO + offload preemption: a live batch sequence is displaced into the
    // warm tier by an interactive arrival, then cancelled while resident.
    let mut sched = fake_scheduler("cancel_warm", 6000);
    sched.record_events(true);
    sched.set_policy(Policy::Slo);
    sched.set_preemption(Preemption::Offload);
    let mut victim = req(1, "a=1;?a=", 2);
    victim.priority = Priority::Batch;
    sched.submit(victim);
    sched.tick().unwrap(); // batch live
    let mut urgent = req(2, "b=2;?b=", 2);
    urgent.priority = Priority::Interactive;
    sched.submit(urgent);
    sched.tick().unwrap(); // interactive preempts; batch offloads
    assert_eq!(sched.metrics.preemptions, 1);
    assert_eq!(sched.tier.n_residents(), 1, "the victim must be warm-resident");

    assert!(sched.cancel(1), "offloaded request must be cancellable");
    assert_eq!(sched.tier.n_residents(), 0, "cancel must drop the warm residency");
    assert_eq!(sched.metrics.cancelled, 1);
    assert_eq!(cancelled_ids(&mut sched), vec![1]);

    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 1, "the cancelled victim never completes or restores");
    assert_eq!(done[0].id, 2);
    assert!(done[0].error.is_none());
    assert_eq!(sched.pool.used_bytes(), 0);
    assert_eq!(sched.metrics.restores, 0);
}

#[test]
fn cancel_while_borrowing_a_shared_prefix_releases_the_pin() {
    let mut sched = fake_scheduler("cancel_prefix", 1 << 30);
    // Request 1 establishes the shared prefix image ("a=11;") and finishes.
    let mut first = req(1, "a=11;b=22;?b=", 2);
    first.prefix_len = 5;
    sched.submit(first);
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert!(done[0].error.is_none());
    assert_eq!(sched.prefix_pins(), 0, "a finished request holds no pin");

    // Request 2 borrows it and is cancelled mid-decode while pinning.
    let mut borrower = req(2, "a=11;c=33;?c=", 40);
    borrower.prefix_len = 5;
    sched.submit(borrower);
    sched.tick().unwrap(); // prefill (acquires the image)
    sched.tick().unwrap(); // mid-decode
    assert_eq!(sched.prefix_pins(), 1, "the borrower must pin the prefix image");
    assert_eq!(sched.prefix_store.pinned_images(), 1);
    assert!(sched.pool.used_bytes() > 0);

    assert!(sched.cancel(2));
    assert_eq!(sched.prefix_pins(), 0, "cancel must release the prefix pin");
    assert_eq!(sched.prefix_store.pinned_images(), 0);
    assert_eq!(sched.pool.used_bytes(), 0);
    assert_eq!(sched.metrics.cancelled, 1);

    // The unpinned image is still reusable by a healthy successor.
    let mut again = req(3, "a=11;d=44;?d=", 2);
    again.prefix_len = 5;
    sched.submit(again);
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, 3);
    assert!(done[0].error.is_none());
    assert_eq!(sched.prefix_pins(), 0);
    assert_eq!(sched.pool.used_bytes(), 0);
}

#[test]
fn cancel_of_an_unknown_id_is_a_no_op() {
    let mut sched = fake_scheduler("cancel_unknown", 1 << 30);
    sched.record_events(true);
    assert!(!sched.cancel(42), "nothing to cancel");
    assert_eq!(sched.metrics.cancelled, 0);
    assert!(cancelled_ids(&mut sched).is_empty());
    // A finished request is equally uncancellable.
    sched.submit(req(1, "a=1;?a=", 2));
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert!(!sched.cancel(1));
    assert_eq!(sched.metrics.cancelled, 0);
}

/// Read one admin counter out of a `stats` snapshot.
fn stat(stats: &[(String, u64)], name: &str) -> u64 {
    stats
        .iter()
        .find(|(n, _)| n == name)
        .unwrap_or_else(|| panic!("stat '{name}' missing from admin snapshot"))
        .1
}

#[test]
fn client_disconnect_mid_stream_cancels_and_releases_everything() {
    let dir = write_fake_artifacts("cancel_socket", '7');
    let stop = Arc::new(AtomicBool::new(false));
    let stop_srv = stop.clone();
    let (bound_tx, bound_rx) = mpsc::channel();
    let server = std::thread::spawn(move || {
        let manifest = Manifest::load(&dir).expect("fake manifest");
        let mut engine = Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
        engine.set_workers(2);
        let sched = Scheduler::new(engine, 1 << 30);
        let cfg = ServerConfig { io_workers: 2, admin_addr: Some("127.0.0.1:0".into()) };
        serve_with(sched, "127.0.0.1:0", cfg, stop_srv, move |b| {
            let _ = bound_tx.send(b);
        })
    });
    let bound = bound_rx.recv().expect("server bound");
    let admin_addr = bound.admin.expect("admin plane enabled");

    // Start a long streaming request and read ONE token line: the request
    // is provably mid-decode, holding a live reservation.
    let conn = TcpStream::connect(bound.data).expect("connect");
    let mut reader = BufReader::new(conn.try_clone().expect("clone"));
    {
        let mut w = &conn;
        w.write_all(b"{\"prompt\": \"a=15;?a=\", \"max_new_tokens\": 300, \"stream\": true}\n")
            .expect("send");
        w.flush().expect("flush");
    }
    let mut line = String::new();
    reader.read_line(&mut line).expect("first token line");
    let j = innerq::util::json::Json::parse(&line).expect("token line parses");
    assert_eq!(j.get("token").as_str(), Some("7"), "streamed token expected: {line}");

    // Hang up mid-stream. The IO worker reports the disconnect; the driver
    // cancels the request and releases its reservation mid-decode.
    drop(reader);
    conn.shutdown(std::net::Shutdown::Both).expect("shutdown");
    drop(conn);

    let mut admin = AdminClient::connect(admin_addr).expect("admin connect");
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = admin.stats().expect("admin stats");
        if stat(&stats, "cancelled") >= 1 && stat(&stats, "pool_used_bytes") == 0 {
            assert_eq!(stat(&stats, "prefix_pins"), 0);
            assert_eq!(stat(&stats, "tier_residents"), 0);
            break;
        }
        assert!(
            Instant::now() < deadline,
            "disconnect was not cancelled within 10s: {stats:?}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // The freed budget serves the next client normally.
    let mut client = Client::connect(bound.data).expect("connect");
    let resp = client.generate("b=22;?b=", 2).expect("completion");
    assert_eq!(resp.get("text").as_str(), Some("77"));
    assert_eq!(resp.get("error").as_str(), None);

    stop.store(true, Ordering::Relaxed);
    server.join().expect("server thread").expect("serve result");
}
