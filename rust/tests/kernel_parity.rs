//! Parity matrix for the blocked SIMD-friendly kernels and the specialized
//! unpackers: **every supported dispatch arm** (scalar plus AVX2/AVX-512/
//! NEON where the host has them) of `qk_inner` / `pv_inner_chunk` /
//! `qk_outer_chunk` must be **bit-identical** to the retained scalar
//! references across bits ∈ {2,3,4}, d_h ∈ {32, 64, 128, 2176 (heap-qsum
//! path)}, all group modes (sym/asym/hybrid), and non-multiple-of-4 row
//! counts / partial-chunk tails — including misaligned code-slice starts
//! (SIMD loads must not assume alignment). The dispatched entry points
//! (whatever `--isa`/`INNERQ_ISA`/detection picked — CI runs this suite
//! under both the native and the forced-scalar arm) are covered by the
//! same matrix, and the per-arm f32 unpackers must agree exactly with the
//! generic bit-loop unpacker.

use innerq::kernels::dispatch;
use innerq::kernels::gemv_inner::{
    pv_inner_chunk, pv_inner_chunk_ref, pv_inner_chunk_with_isa, qk_inner, qk_inner_ref,
    qk_inner_with_isa,
};
use innerq::kernels::gemv_outer::{qk_outer_chunk, qk_outer_chunk_ref, qk_outer_chunk_with_isa};
use innerq::kernels::zeff_planes;
use innerq::quant::group::{quantize, Mode};
use innerq::quant::packing::{pack, packed_len, unpack, unpack32, unpack32_f32, unpack32_f32_isa};
use innerq::quant::GroupParams;
use innerq::util::ptest::normal_vec;
use innerq::util::rng::Rng;

/// Quantize an n x d_h matrix in the InnerQ key layout (per-token groups).
fn build_key_rows(vals: &[f32], d_h: usize, bits: u8, mode: Mode) -> (Vec<u8>, Vec<GroupParams>) {
    let mut codes = Vec::new();
    let mut params = Vec::new();
    for row in vals.chunks_exact(d_h) {
        for g in row.chunks_exact(32) {
            let mut raw = [0u8; 32];
            params.push(quantize(mode, g, bits, &mut raw));
            pack(&raw, bits, &mut codes);
        }
    }
    (codes, params)
}

/// Quantize 32 tokens x d_h (token-major) into one InnerQ value chunk
/// (per-channel groups along the token axis, codes stored token-major).
fn build_val_chunk(vals: &[f32], d_h: usize, bits: u8, mode: Mode) -> (Vec<u8>, Vec<GroupParams>) {
    assert_eq!(vals.len(), 32 * d_h);
    let mut params = Vec::new();
    let mut col = [0f32; 32];
    let mut ccodes = [0u8; 32];
    let mut raw = vec![0u8; 32 * d_h];
    for c in 0..d_h {
        for (t, v) in col.iter_mut().enumerate() {
            *v = vals[t * d_h + c];
        }
        params.push(quantize(mode, &col, bits, &mut ccodes));
        for (t, &cc) in ccodes.iter().enumerate() {
            raw[t * d_h + c] = cc;
        }
    }
    let mut codes = Vec::new();
    for t in 0..32 {
        pack(&raw[t * d_h..(t + 1) * d_h], bits, &mut codes);
    }
    (codes, params)
}

const MODES: [Mode; 3] = [Mode::Sym, Mode::Asym, Mode::Hybrid];

/// Copy `codes` behind `pad` junk bytes so the returned offset slice starts
/// at a deliberately misaligned address — the SIMD arms use unaligned loads
/// and must not care. (An odd offset into any allocation is misaligned for
/// every vector width.)
fn misaligned(codes: &[u8], pad: usize) -> Vec<u8> {
    let mut padded = vec![0xA5u8; pad];
    padded.extend_from_slice(codes);
    padded
}

#[test]
fn qk_blocked_bit_identical_across_full_matrix() {
    let mut rng = Rng::new(0xB10C);
    // Row counts deliberately include every tail length mod 4 and the
    // single-row case; d_h = 2176 (68 groups) exercises the heap qsum path.
    let row_counts = [1usize, 2, 3, 4, 5, 6, 7, 8, 11, 33];
    for d_h in [32usize, 64, 128, 2176] {
        for bits in [2u8, 3, 4] {
            for mode in MODES {
                // Keep the giant geometry cheap: fewer rows there.
                let ns: &[usize] = if d_h >= 2048 { &[1, 3, 5] } else { &row_counts };
                for &n in ns {
                    let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
                    let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.1);
                    let (codes, params) = build_key_rows(&keys, d_h, bits, mode);
                    let (sc, ze) = zeff_planes(&params, bits);
                    let mut fast = vec![0f32; n];
                    let mut refr = vec![0f32; n];
                    qk_inner(&q, &codes, &sc, &ze, bits, d_h, &mut fast);
                    qk_inner_ref(&q, &codes, &sc, &ze, bits, d_h, &mut refr);
                    // Bit-identical, not approximately equal: compare bits so
                    // -0.0 vs 0.0 or NaN drift would also be caught.
                    for (j, (a, b)) in fast.iter().zip(&refr).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "d_h={d_h} bits={bits} {mode:?} n={n} row {j}: {a} vs {b}"
                        );
                    }
                    // Every dispatch arm the host supports, against the same
                    // reference — and, at the common geometry, from
                    // misaligned code-slice starts.
                    for isa in dispatch::supported() {
                        let mut arm = vec![0f32; n];
                        qk_inner_with_isa(isa, &q, &codes, &sc, &ze, bits, d_h, &mut arm);
                        for (j, (a, b)) in arm.iter().zip(&refr).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "{isa} d_h={d_h} bits={bits} {mode:?} n={n} row {j}: {a} vs {b}"
                            );
                        }
                        if d_h == 128 {
                            for pad in [1usize, 3] {
                                let padded = misaligned(&codes, pad);
                                let mut arm = vec![0f32; n];
                                qk_inner_with_isa(
                                    isa,
                                    &q,
                                    &padded[pad..],
                                    &sc,
                                    &ze,
                                    bits,
                                    d_h,
                                    &mut arm,
                                );
                                for (j, (a, b)) in arm.iter().zip(&refr).enumerate() {
                                    assert_eq!(
                                        a.to_bits(),
                                        b.to_bits(),
                                        "{isa} misaligned(+{pad}) d_h={d_h} bits={bits} \
                                         {mode:?} n={n} row {j}: {a} vs {b}"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn pv_blocked_bit_identical_across_full_matrix() {
    let mut rng = Rng::new(0xB10D);
    for d_h in [32usize, 64, 128, 2176] {
        for bits in [2u8, 3, 4] {
            for mode in MODES {
                let vals = normal_vec(&mut rng, 32 * d_h, 1.0, 0.1);
                let p = normal_vec(&mut rng, 32, 0.3, 0.0);
                let (codes, params) = build_val_chunk(&vals, d_h, bits, mode);
                let (sc, ze) = zeff_planes(&params, bits);
                // Accumulate on top of a non-zero context, like attend does.
                let init = normal_vec(&mut rng, d_h, 0.5, 0.0);
                let mut fast = init.clone();
                let mut refr = init.clone();
                pv_inner_chunk(&p, &codes, &sc, &ze, bits, d_h, &mut fast);
                pv_inner_chunk_ref(&p, &codes, &sc, &ze, bits, d_h, &mut refr);
                for (c, (a, b)) in fast.iter().zip(&refr).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "d_h={d_h} bits={bits} {mode:?} channel {c}: {a} vs {b}"
                    );
                }
                for isa in dispatch::supported() {
                    let mut arm = init.clone();
                    pv_inner_chunk_with_isa(isa, &p, &codes, &sc, &ze, bits, d_h, &mut arm);
                    for (c, (a, b)) in arm.iter().zip(&refr).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{isa} d_h={d_h} bits={bits} {mode:?} channel {c}: {a} vs {b}"
                        );
                    }
                    if d_h == 128 {
                        for pad in [1usize, 3] {
                            let padded = misaligned(&codes, pad);
                            let mut arm = init.clone();
                            pv_inner_chunk_with_isa(
                                isa,
                                &p,
                                &padded[pad..],
                                &sc,
                                &ze,
                                bits,
                                d_h,
                                &mut arm,
                            );
                            for (c, (a, b)) in arm.iter().zip(&refr).enumerate() {
                                assert_eq!(
                                    a.to_bits(),
                                    b.to_bits(),
                                    "{isa} misaligned(+{pad}) d_h={d_h} bits={bits} \
                                     {mode:?} channel {c}: {a} vs {b}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Quantize 32 tokens x d_h (token-major) into one KIVI key chunk:
/// per-channel groups along the token axis, codes stored token-major.
fn build_outer_key_chunk(
    vals: &[f32],
    d_h: usize,
    bits: u8,
    mode: Mode,
) -> (Vec<u8>, Vec<GroupParams>) {
    assert_eq!(vals.len(), 32 * d_h);
    let mut params = vec![GroupParams::default(); d_h];
    let mut raw = vec![0u8; 32 * d_h];
    let mut col = [0f32; 32];
    let mut ccodes = [0u8; 32];
    for c in 0..d_h {
        for (t, v) in col.iter_mut().enumerate() {
            *v = vals[t * d_h + c];
        }
        params[c] = quantize(mode, &col, bits, &mut ccodes);
        for (t, &cc) in ccodes.iter().enumerate() {
            raw[t * d_h + c] = cc;
        }
    }
    let mut codes = Vec::new();
    for t in 0..32 {
        pack(&raw[t * d_h..(t + 1) * d_h], bits, &mut codes);
    }
    (codes, params)
}

#[test]
fn qk_outer_blocked_bit_identical_across_full_matrix() {
    let mut rng = Rng::new(0xB110);
    // Row counts cover every tail length mod 4, the single-row case, and
    // the full chunk (tails < 32 arise transiently during bulk prefill).
    let row_counts = [1usize, 2, 3, 4, 5, 7, 8, 13, 31, 32];
    for d_h in [32usize, 64, 128] {
        for bits in [2u8, 3, 4] {
            for mode in MODES {
                let keys = normal_vec(&mut rng, 32 * d_h, 1.0, 0.1);
                let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
                let (codes, params) = build_outer_key_chunk(&keys, d_h, bits, mode);
                let (sc, ze) = zeff_planes(&params, bits);
                for &n in &row_counts {
                    let mut scratch_a = vec![0f32; d_h];
                    let mut scratch_b = vec![0f32; d_h];
                    let mut fast = vec![0f32; n];
                    let mut refr = vec![0f32; n];
                    qk_outer_chunk(&q, &codes, &sc, &ze, bits, d_h, &mut scratch_a, &mut fast);
                    qk_outer_chunk_ref(&q, &codes, &sc, &ze, bits, d_h, &mut scratch_b, &mut refr);
                    for (j, (a, b)) in fast.iter().zip(&refr).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "d_h={d_h} bits={bits} {mode:?} n={n} row {j}: {a} vs {b}"
                        );
                    }
                    for isa in dispatch::supported() {
                        let mut arm = vec![0f32; n];
                        qk_outer_chunk_with_isa(
                            isa,
                            &q,
                            &codes,
                            &sc,
                            &ze,
                            bits,
                            d_h,
                            &mut scratch_a,
                            &mut arm,
                        );
                        for (j, (a, b)) in arm.iter().zip(&refr).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "{isa} d_h={d_h} bits={bits} {mode:?} n={n} row {j}: {a} vs {b}"
                            );
                        }
                        if d_h == 128 && n == 13 {
                            // One misaligned pass per arm at the partial-tail
                            // geometry (odd n exercises the 1-row tail too).
                            for pad in [1usize, 3] {
                                let padded = misaligned(&codes, pad);
                                let mut arm = vec![0f32; n];
                                qk_outer_chunk_with_isa(
                                    isa,
                                    &q,
                                    &padded[pad..],
                                    &sc,
                                    &ze,
                                    bits,
                                    d_h,
                                    &mut scratch_a,
                                    &mut arm,
                                );
                                for (j, (a, b)) in arm.iter().zip(&refr).enumerate() {
                                    assert_eq!(
                                        a.to_bits(),
                                        b.to_bits(),
                                        "{isa} misaligned(+{pad}) d_h={d_h} bits={bits} \
                                         {mode:?} n={n} row {j}: {a} vs {b}"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn specialized_unpackers_match_generic_reference() {
    let mut rng = Rng::new(0xB10E);
    for bits in 1..=8u8 {
        for _ in 0..500 {
            let codes: Vec<u8> =
                (0..32).map(|_| (rng.next_u64() & ((1u64 << bits) - 1)) as u8).collect();
            let mut packed = Vec::new();
            pack(&codes, bits, &mut packed);
            assert_eq!(packed.len(), packed_len(32, bits));

            let mut generic = vec![0u8; 32];
            unpack(&packed, bits, 32, &mut generic);
            assert_eq!(&generic[..], &codes[..], "generic round trip bits={bits}");

            let mut fast_u8 = [0u8; 32];
            unpack32(&packed, bits, &mut fast_u8);
            assert_eq!(&fast_u8[..], &codes[..], "u8 fast path bits={bits}");

            let mut fast_f32 = [0f32; 32];
            unpack32_f32(&packed, bits, &mut fast_f32);
            for i in 0..32 {
                assert_eq!(fast_f32[i], codes[i] as f32, "f32 fast path bits={bits} i={i}");
            }
        }
    }
}

#[test]
fn unpackers_handle_exact_length_group_slices() {
    // The kernels hand the unpackers slices that end exactly at the group
    // boundary (the last group of a row); the u64 loads must not need slack.
    let mut rng = Rng::new(0xB10F);
    for bits in [2u8, 3, 4] {
        let codes: Vec<u8> =
            (0..32).map(|_| (rng.next_u64() & ((1u64 << bits) - 1)) as u8).collect();
        let mut packed = Vec::new();
        pack(&codes, bits, &mut packed);
        let exact = &packed[..packed_len(32, bits)];
        let mut out = [0f32; 32];
        unpack32_f32(exact, bits, &mut out);
        for i in 0..32 {
            assert_eq!(out[i], codes[i] as f32, "bits={bits} i={i}");
        }
    }
}

#[test]
fn isa_unpackers_match_scalar_across_arms() {
    // The per-arm unpackers (AVX2/AVX-512 srlv+gather, NEON vshl) must agree
    // exactly with the scalar fast path — from exact-length group slices
    // (no slack bytes after the group: the b3 clamped-container scheme
    // exists precisely so the 4-byte loads never read past them) and from
    // misaligned slice starts.
    let mut rng = Rng::new(0xB111);
    for isa in dispatch::supported() {
        for bits in [2u8, 3, 4] {
            for _ in 0..200 {
                let codes: Vec<u8> =
                    (0..32).map(|_| (rng.next_u64() & ((1u64 << bits) - 1)) as u8).collect();
                let mut packed = Vec::new();
                pack(&codes, bits, &mut packed);
                let exact = &packed[..packed_len(32, bits)];
                let mut out = [0f32; 32];
                unpack32_f32_isa(isa, exact, bits, &mut out);
                for i in 0..32 {
                    assert_eq!(out[i], codes[i] as f32, "{isa} bits={bits} i={i}");
                }
                for pad in [1usize, 3] {
                    let padded = misaligned(exact, pad);
                    let mut out = [0f32; 32];
                    unpack32_f32_isa(isa, &padded[pad..], bits, &mut out);
                    for i in 0..32 {
                        assert_eq!(
                            out[i], codes[i] as f32,
                            "{isa} misaligned(+{pad}) bits={bits} i={i}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn forced_arm_switching_is_consistent_with_explicit_dispatch() {
    // Pinning an arm via dispatch::set_active must make the public
    // dispatched entry points behave exactly like the explicit `*_with_isa`
    // calls — this is the in-process equivalent of the INNERQ_ISA override
    // CI uses for the forced-scalar test pass. Serialized against nothing:
    // this is the only test in the binary that mutates the global arm, and
    // it restores auto-detection before returning (even on panic the
    // process dies anyway).
    let mut rng = Rng::new(0xB112);
    let d_h = 128;
    let n = 7;
    let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
    let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.1);
    for isa in dispatch::supported() {
        for bits in [2u8, 3, 4] {
            let (codes, params) = build_key_rows(&keys, d_h, bits, Mode::Hybrid);
            let (sc, ze) = zeff_planes(&params, bits);
            let mut explicit = vec![0f32; n];
            qk_inner_with_isa(isa, &q, &codes, &sc, &ze, bits, d_h, &mut explicit);
            dispatch::set_active(Some(isa)).expect("supported arm must pin");
            assert_eq!(dispatch::active(), isa);
            let mut dispatched = vec![0f32; n];
            qk_inner(&q, &codes, &sc, &ze, bits, d_h, &mut dispatched);
            dispatch::set_active(None).expect("clearing the pin never fails");
            for (j, (a, b)) in dispatched.iter().zip(&explicit).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "pinned {isa} bits={bits} row {j}: {a} vs {b}"
                );
            }
        }
    }
}
