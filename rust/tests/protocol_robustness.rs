//! Adversarial protocol matrix for the TCP JSON server: every hostile line
//! — truncated JSON, over-long lines, non-UTF8 bytes, deeply-nested garbage
//! — must be answered in-band with an `{"error": ...}` line, and none of it
//! may poison scheduler state: valid requests interleaved with (and
//! following) the garbage must still complete with the exact expected text,
//! on the same connection and on fresh ones.

use innerq::coordinator::{Engine, Scheduler};
use innerq::runtime::Manifest;
use innerq::server::{serve, Client, MAX_LINE_BYTES};
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::QuantMethod;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

struct TestServer {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
    handle: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

impl TestServer {
    fn start(tag: &str) -> TestServer {
        let dir = write_fake_artifacts(tag, '7');
        let stop = Arc::new(AtomicBool::new(false));
        let (addr_tx, addr_rx) = mpsc::channel();
        let stop_srv = stop.clone();
        let handle = std::thread::spawn(move || {
            let manifest = Manifest::load(&dir).expect("fake manifest");
            let mut engine =
                Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
            engine.set_workers(2);
            let sched = Scheduler::new(engine, 1 << 30);
            serve(sched, "127.0.0.1:0", stop_srv, move |a| {
                let _ = addr_tx.send(a);
            })
        });
        let addr = addr_rx.recv().expect("server bound");
        TestServer { stop, addr, handle: Some(handle) }
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr); // poke the acceptor awake
        if let Some(h) = self.handle.take() {
            h.join().expect("server thread").expect("serve result");
        }
    }
}

/// A raw-byte connection (the [`Client`] API only speaks `&str`, which can
/// never produce invalid UTF-8 on the wire).
struct RawConn {
    conn: TcpStream,
    reader: BufReader<TcpStream>,
}

impl RawConn {
    fn connect(addr: SocketAddr) -> RawConn {
        let conn = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(conn.try_clone().expect("clone"));
        RawConn { conn, reader }
    }

    /// Send raw bytes (the newline is the caller's job) and read one
    /// response line.
    fn send_raw(&mut self, bytes: &[u8]) -> innerq::util::json::Json {
        self.conn.write_all(bytes).expect("write");
        self.conn.flush().expect("flush");
        let mut resp = String::new();
        self.reader.read_line(&mut resp).expect("read response");
        innerq::util::json::Json::parse(&resp).expect("response parses")
    }

    fn error_of(&mut self, bytes: &[u8]) -> String {
        let resp = self.send_raw(bytes);
        resp.get("error")
            .as_str()
            .unwrap_or_else(|| panic!("expected an error line, got {}", resp.dump()))
            .to_string()
    }
}

#[test]
fn hostile_lines_are_answered_in_band_and_never_poison_the_scheduler() {
    let server = TestServer::start("proto_matrix");
    let mut raw = RawConn::connect(server.addr);

    // -- truncated JSON: a request cut mid-object (newline still present).
    let err = raw.error_of(b"{\"prompt\": \"a=1\n");
    assert!(err.contains("JSON"), "truncated JSON must fail parse: {err}");
    // Truncated mid-string-escape as well.
    let err = raw.error_of(b"{\"prompt\": \"ab\\\n");
    assert!(err.contains("JSON"), "truncated escape must fail parse: {err}");

    // -- non-UTF8 bytes.
    let err = raw.error_of(b"\xff\xfe{\"prompt\": \"a=1;?a=\"}\n");
    assert!(err.contains("UTF-8"), "non-UTF8 must be named in-band: {err}");

    // -- deeply-nested garbage: the parser's depth guard answers instead of
    // the reader thread blowing its stack.
    let mut bomb = Vec::new();
    bomb.extend_from_slice(&b"[".repeat(100_000));
    bomb.push(b'1');
    bomb.extend_from_slice(&b"]".repeat(100_000));
    bomb.push(b'\n');
    let err = raw.error_of(&bomb);
    assert!(err.contains("nesting"), "nesting bomb must be rejected: {err}");

    // -- oversized line: streamed past the cap, answered, and the
    // connection resynchronizes at the newline.
    let mut huge = Vec::with_capacity(MAX_LINE_BYTES + 64);
    huge.extend_from_slice(b"{\"prompt\": \"");
    huge.extend_from_slice(&b"a".repeat(MAX_LINE_BYTES + 1));
    huge.extend_from_slice(b"\"}\n");
    let err = raw.error_of(&huge);
    assert!(err.contains("exceeds"), "over-long line must be capped: {err}");

    // -- the same connection still serves real work after all of the above.
    let resp = raw.send_raw(b"{\"prompt\": \"a=15;?a=\", \"max_new_tokens\": 3}\n");
    assert_eq!(resp.get("text").as_str(), Some("777"));
    assert_eq!(resp.get("error").as_str(), None);

    // -- and a fresh connection sees a healthy scheduler too.
    let mut client = Client::connect(server.addr).expect("connect");
    let resp = client.generate("b=22;?b=", 2).expect("completion");
    assert_eq!(resp.get("text").as_str(), Some("77"));
    assert_eq!(resp.get("error").as_str(), None);
}

#[test]
fn garbage_interleaved_with_valid_requests_keeps_results_exact() {
    let server = TestServer::start("proto_interleave");
    let mut raw = RawConn::connect(server.addr);
    // Alternate hostile and valid lines; every valid one must come back
    // exact, every hostile one as an error, in order, with nothing dropped.
    for round in 0..3 {
        let err = raw.error_of(b"]]]]}}}{{{[[[\n");
        assert!(err.contains("JSON"), "round {round}: {err}");
        let err = raw.error_of(b"\x80\x81\x82\n");
        assert!(err.contains("UTF-8"), "round {round}: {err}");
        let resp = raw.send_raw(b"{\"prompt\": \"c=33;?c=\", \"max_new_tokens\": 2}\n");
        assert_eq!(resp.get("text").as_str(), Some("77"), "round {round}");
        assert_eq!(resp.get("error").as_str(), None, "round {round}");
    }
}
