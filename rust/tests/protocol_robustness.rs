//! Adversarial protocol matrix and fuzz harness for the TCP JSON server:
//! every hostile line — truncated JSON, over-long lines, non-UTF8 bytes,
//! deeply-nested garbage, seeded structure-aware mutations of valid
//! requests — must be answered in-band with an `{"error": ...}` line or
//! parsed as a request, and none of it may poison scheduler state: valid
//! requests interleaved with (and following) the garbage must still
//! complete with the exact expected text, on the same connection and on
//! fresh ones.
//!
//! The mutation engine ([`mutate_line`]) and the pure byte-level harness
//! ([`innerq::server::fuzz_protocol_bytes`]) share one corpus philosophy:
//! fixed seeds in CI (scale with `INNERQ_FUZZ_ROUNDS`), and the pure
//! harness doubles as a `cargo fuzz` target body.

use innerq::coordinator::{Engine, Scheduler};
use innerq::runtime::Manifest;
use innerq::server::{fuzz_protocol_bytes, serve, Client, MAX_LINE_BYTES};
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::util::json::Json;
use innerq::util::rng::Rng;
use innerq::QuantMethod;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

struct TestServer {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
    handle: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

impl TestServer {
    fn start(tag: &str) -> TestServer {
        let dir = write_fake_artifacts(tag, '7');
        let stop = Arc::new(AtomicBool::new(false));
        let (addr_tx, addr_rx) = mpsc::channel();
        let stop_srv = stop.clone();
        let handle = std::thread::spawn(move || {
            let manifest = Manifest::load(&dir).expect("fake manifest");
            let mut engine =
                Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
            engine.set_workers(2);
            let sched = Scheduler::new(engine, 1 << 30);
            serve(sched, "127.0.0.1:0", stop_srv, move |a| {
                let _ = addr_tx.send(a);
            })
        });
        let addr = addr_rx.recv().expect("server bound");
        TestServer { stop, addr, handle: Some(handle) }
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr); // poke the acceptor awake
        if let Some(h) = self.handle.take() {
            h.join().expect("server thread").expect("serve result");
        }
    }
}

/// A raw-byte connection (the [`Client`] API only speaks `&str`, which can
/// never produce invalid UTF-8 on the wire).
struct RawConn {
    conn: TcpStream,
    reader: BufReader<TcpStream>,
}

impl RawConn {
    fn connect(addr: SocketAddr) -> RawConn {
        let conn = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(conn.try_clone().expect("clone"));
        RawConn { conn, reader }
    }

    /// Send raw bytes (the newline is the caller's job) and read one
    /// response line.
    fn send_raw(&mut self, bytes: &[u8]) -> innerq::util::json::Json {
        self.conn.write_all(bytes).expect("write");
        self.conn.flush().expect("flush");
        let mut resp = String::new();
        self.reader.read_line(&mut resp).expect("read response");
        innerq::util::json::Json::parse(&resp).expect("response parses")
    }

    fn error_of(&mut self, bytes: &[u8]) -> String {
        let resp = self.send_raw(bytes);
        resp.get("error")
            .as_str()
            .unwrap_or_else(|| panic!("expected an error line, got {}", resp.dump()))
            .to_string()
    }
}

#[test]
fn hostile_lines_are_answered_in_band_and_never_poison_the_scheduler() {
    let server = TestServer::start("proto_matrix");
    let mut raw = RawConn::connect(server.addr);

    // -- truncated JSON: a request cut mid-object (newline still present).
    let err = raw.error_of(b"{\"prompt\": \"a=1\n");
    assert!(err.contains("JSON"), "truncated JSON must fail parse: {err}");
    // Truncated mid-string-escape as well.
    let err = raw.error_of(b"{\"prompt\": \"ab\\\n");
    assert!(err.contains("JSON"), "truncated escape must fail parse: {err}");

    // -- non-UTF8 bytes.
    let err = raw.error_of(b"\xff\xfe{\"prompt\": \"a=1;?a=\"}\n");
    assert!(err.contains("UTF-8"), "non-UTF8 must be named in-band: {err}");

    // -- deeply-nested garbage: the parser's depth guard answers instead of
    // the reader thread blowing its stack.
    let mut bomb = Vec::new();
    bomb.extend_from_slice(&b"[".repeat(100_000));
    bomb.push(b'1');
    bomb.extend_from_slice(&b"]".repeat(100_000));
    bomb.push(b'\n');
    let err = raw.error_of(&bomb);
    assert!(err.contains("nesting"), "nesting bomb must be rejected: {err}");

    // -- oversized line: streamed past the cap, answered, and the
    // connection resynchronizes at the newline.
    let mut huge = Vec::with_capacity(MAX_LINE_BYTES + 64);
    huge.extend_from_slice(b"{\"prompt\": \"");
    huge.extend_from_slice(&b"a".repeat(MAX_LINE_BYTES + 1));
    huge.extend_from_slice(b"\"}\n");
    let err = raw.error_of(&huge);
    assert!(err.contains("exceeds"), "over-long line must be capped: {err}");

    // -- the same connection still serves real work after all of the above.
    let resp = raw.send_raw(b"{\"prompt\": \"a=15;?a=\", \"max_new_tokens\": 3}\n");
    assert_eq!(resp.get("text").as_str(), Some("777"));
    assert_eq!(resp.get("error").as_str(), None);

    // -- and a fresh connection sees a healthy scheduler too.
    let mut client = Client::connect(server.addr).expect("connect");
    let resp = client.generate("b=22;?b=", 2).expect("completion");
    assert_eq!(resp.get("text").as_str(), Some("77"));
    assert_eq!(resp.get("error").as_str(), None);
}

#[test]
fn garbage_interleaved_with_valid_requests_keeps_results_exact() {
    let server = TestServer::start("proto_interleave");
    let mut raw = RawConn::connect(server.addr);
    // Alternate hostile and valid lines; every valid one must come back
    // exact, every hostile one as an error, in order, with nothing dropped.
    for round in 0..3 {
        let err = raw.error_of(b"]]]]}}}{{{[[[\n");
        assert!(err.contains("JSON"), "round {round}: {err}");
        let err = raw.error_of(b"\x80\x81\x82\n");
        assert!(err.contains("UTF-8"), "round {round}: {err}");
        let resp = raw.send_raw(b"{\"prompt\": \"c=33;?c=\", \"max_new_tokens\": 2}\n");
        assert_eq!(resp.get("text").as_str(), Some("77"), "round {round}");
        assert_eq!(resp.get("error").as_str(), None, "round {round}");
    }
}

// ---------------------------------------------------------------------------
// Structure-aware fuzz harness: seeded, deterministic mutations of a valid
// request, fired at a live server, with a tagged sentinel request proving
// after every round that the scheduler still produces exact completions.
// ---------------------------------------------------------------------------

/// Rounds for the seeded fuzz corpus. CI raises this via
/// `INNERQ_FUZZ_ROUNDS`; the default keeps `cargo test` quick.
fn fuzz_rounds(default: usize) -> usize {
    std::env::var("INNERQ_FUZZ_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// One structure-aware mutation of a valid request line. Always
/// newline-terminated so a hostile frame cannot swallow the sentinel that
/// follows it; unterminated (split) frames are exercised separately where
/// the test controls reassembly.
fn mutate_line(rng: &mut Rng) -> Vec<u8> {
    let template = b"{\"prompt\": \"a=15;?a=\", \"max_new_tokens\": 3}".to_vec();
    let mut line = match rng.next_range(5) {
        // Truncation: cut the frame mid-object / mid-string / mid-escape.
        0 => template[..1 + rng.next_range(template.len() - 1)].to_vec(),
        // Byte flips: 1-4 random positions xor'd with a random byte
        // (possibly producing invalid UTF-8, control bytes, or embedded
        // newlines that re-frame the line — all must be answered).
        1 => {
            let mut l = template;
            for _ in 0..1 + rng.next_range(4) {
                let i = rng.next_range(l.len());
                l[i] ^= (rng.next_u64() % 255 + 1) as u8;
            }
            l
        }
        // Nesting bomb: deeper than the parser's depth guard.
        2 => {
            let depth = 150 + rng.next_range(400);
            let mut l = b"{\"prompt\": ".to_vec();
            l.extend(std::iter::repeat(b'[').take(depth));
            l.push(b'1');
            l.extend(std::iter::repeat(b']').take(depth));
            l.push(b'}');
            l
        }
        // Random bytes, newline-free.
        3 => {
            let n = 1 + rng.next_range(64);
            (0..n)
                .map(|_| {
                    let b = (rng.next_u64() % 256) as u8;
                    if b == b'\n' {
                        b'\r'
                    } else {
                        b
                    }
                })
                .collect()
        }
        // Structurally valid JSON that is not a valid request.
        _ => match rng.next_range(4) {
            0 => b"{\"max_new_tokens\": 3}".to_vec(),
            1 => b"{\"prompt\": 7}".to_vec(),
            2 => b"{\"prompt\": \"a=1;?a=\", \"priority\": \"warp\"}".to_vec(),
            _ => b"{\"prompt\": \"a=1;?a=\", \"stream\": \"yes\"}".to_vec(),
        },
    };
    line.push(b'\n');
    line
}

#[test]
fn seeded_fuzz_corpus_is_answered_in_band_and_never_poisons_the_scheduler() {
    let server = TestServer::start("proto_fuzz");
    let mut raw = RawConn::connect(server.addr);
    let mut rng = Rng::new(0xf077_0008 ^ 0x1234_5678_9abc_def0);
    let rounds = fuzz_rounds(24);
    for round in 0..rounds {
        // A pipelined burst of hostile frames in one write...
        let mut burst = Vec::new();
        for _ in 0..1 + rng.next_range(4) {
            burst.extend(mutate_line(&mut rng));
        }
        raw.conn.write_all(&burst).expect("write burst");
        raw.conn.flush().expect("flush");

        // ...then a tagged sentinel. Everything the server says before the
        // sentinel's completion must be well-formed JSON (in-band answers,
        // never silence, never a closed socket), and the sentinel itself
        // must complete exactly — proof the garbage reached no scheduler
        // state it shouldn't have.
        let tag = format!("sentinel-{round}");
        let sentinel = format!(
            "{{\"prompt\": \"a=15;?a=\", \"max_new_tokens\": 2, \"tag\": \"{tag}\"}}\n"
        );
        raw.conn.write_all(sentinel.as_bytes()).expect("write sentinel");
        raw.conn.flush().expect("flush");
        loop {
            let mut resp = String::new();
            let n = raw.reader.read_line(&mut resp).expect("read response");
            assert!(n > 0, "round {round}: server closed the connection");
            let j = Json::parse(&resp)
                .unwrap_or_else(|e| panic!("round {round}: unparseable line {resp:?}: {e}"));
            if j.get("tag").as_str() == Some(tag.as_str()) && !matches!(j.get("text"), Json::Null) {
                assert_eq!(j.get("text").as_str(), Some("77"), "round {round}");
                assert_eq!(j.get("error").as_str(), None, "round {round}");
                break;
            }
        }
    }
}

#[test]
fn frames_split_across_read_boundaries_reassemble_exactly() {
    let server = TestServer::start("proto_split");
    let mut raw = RawConn::connect(server.addr);
    // Drip a valid request one byte at a time with real syscall boundaries:
    // the IO worker's incremental assembler must reassemble it bit-exact.
    let line = b"{\"prompt\": \"a=15;?a=\", \"max_new_tokens\": 3, \"tag\": \"drip\"}\n";
    for chunk in line.chunks(1) {
        raw.conn.write_all(chunk).expect("write byte");
        raw.conn.flush().expect("flush");
        if chunk[0] == b',' {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
    let mut resp = String::new();
    raw.reader.read_line(&mut resp).expect("read");
    let j = Json::parse(&resp).expect("parses");
    assert_eq!(j.get("tag").as_str(), Some("drip"));
    assert_eq!(j.get("text").as_str(), Some("777"));

    // And the converse: two requests plus a trailing partial frame in ONE
    // write. Both complete (in order), the partial stays buffered until its
    // newline arrives later.
    let mut pipelined = Vec::new();
    pipelined.extend_from_slice(b"{\"prompt\": \"b=22;?b=\", \"max_new_tokens\": 1, \"tag\": \"p1\"}\n");
    pipelined.extend_from_slice(b"{\"prompt\": \"c=33;?c=\", \"max_new_tokens\": 2, \"tag\": \"p2\"}\n");
    pipelined.extend_from_slice(b"{\"prompt\": \"d=44;?d=\", \"max_new");
    raw.conn.write_all(&pipelined).expect("write pipelined");
    raw.conn.flush().expect("flush");
    for (tag, text) in [("p1", "7"), ("p2", "77")] {
        let mut resp = String::new();
        raw.reader.read_line(&mut resp).expect("read");
        let j = Json::parse(&resp).expect("parses");
        assert_eq!(j.get("tag").as_str(), Some(tag));
        assert_eq!(j.get("text").as_str(), Some(text));
    }
    // Complete the partial frame; it must now parse as one whole request.
    raw.conn
        .write_all(b"_tokens\": 1, \"tag\": \"p3\"}\n")
        .expect("write tail");
    raw.conn.flush().expect("flush");
    let mut resp = String::new();
    raw.reader.read_line(&mut resp).expect("read");
    let j = Json::parse(&resp).expect("parses");
    assert_eq!(j.get("tag").as_str(), Some("p3"));
    assert_eq!(j.get("text").as_str(), Some("7"));
}

#[test]
fn pure_byte_fuzz_harness_accepts_a_seeded_corpus() {
    // `fuzz_protocol_bytes` is the cargo-fuzz target body; here it chews a
    // fixed-seed random corpus so CI exercises the same code path without
    // the fuzzer. Any panic inside (framing invariant, parser crash) fails
    // the test.
    let mut rng = Rng::new(0xc0de_feed_0008);
    for _ in 0..fuzz_rounds(64) {
        let n = rng.next_range(600);
        let data: Vec<u8> = (0..n).map(|_| (rng.next_u64() % 256) as u8).collect();
        fuzz_protocol_bytes(&data);
    }
    // Handcrafted seeds: valid frame, empty input, bare newlines, an
    // over-cap line, and a split-friendly partial frame.
    fuzz_protocol_bytes(b"{\"prompt\": \"a=1;?a=\", \"max_new_tokens\": 2}\n");
    fuzz_protocol_bytes(b"");
    fuzz_protocol_bytes(b"\n\n\n");
    fuzz_protocol_bytes(b"{\"prompt\": \"a=1;?a");
    let mut huge = vec![b'a'; MAX_LINE_BYTES + 2];
    huge.push(b'\n');
    fuzz_protocol_bytes(&huge);
}
