//! Pipelined-decode determinism and partial-eviction restore (ISSUE 5
//! acceptance):
//!
//! * `overlap` decode (the full task graph of fused append+attend jobs)
//!   must be **byte-identical** to the retained `barrier` oracle — logits
//!   and serialized cache bytes — across worker counts {1, 2, 4, 8},
//!   quantization layouts (inner/outer grouping × sym/asym/hybrid modes),
//!   and multi-sequence batches, with windows small enough that the
//!   quantized segments (and their eviction cadences) are genuinely
//!   exercised on the fake model.
//! * A sequence restored from per-layer frames whose fp-window frames were
//!   evicted (quantized middle from the tier, windows recomputed from a
//!   prefill pass) must be bit-identical to a never-offloaded twin, and
//!   keep decoding bit-identically.
//! * The whole session must be byte-identical under every kernel dispatch
//!   arm the host supports (scalar vs AVX2/AVX-512/NEON) — ISA selection
//!   is a throughput choice, never an output change.

use innerq::cache::store::{
    prefix_base_hash, restore_sequence_frames, restore_sequence_frames_with, snapshot_sequence,
    snapshot_sequence_frames, snapshot_sequence_frames_by_ref, FrameKind, PrefixStore, WarmTier,
};
use innerq::coordinator::{Engine, PipelineMode, PrefixOutcome};
use innerq::quant::group::Mode;
use innerq::quant::{Grouping, MethodConfig};
use innerq::runtime::Manifest;
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::QuantMethod;

/// A quantization config with windows small enough that the fake model's
/// short sequences cross both the key and value eviction cadences (sink 4 +
/// recent 8; the outer key layout still needs 32 more tokens per chunk).
fn small_window_cfg(grouping: Grouping, mode: Mode) -> MethodConfig {
    let mut cfg = QuantMethod::InnerQBase.config();
    cfg.w_sink = 4;
    cfg.w_recent = 8;
    cfg.key_bits = 3;
    cfg.val_bits = 3;
    cfg.key_mode = mode;
    cfg.val_mode = mode;
    cfg.key_grouping = grouping;
    cfg.val_grouping = grouping;
    // Key norm is an inner-grouping (InnerQ) feature.
    cfg.key_norm = grouping == Grouping::Inner;
    cfg
}

/// Long enough that the quantized middle holds real mass: 48 prefill tokens
/// plus the decode steps below push outer-grouped keys past a 32-token
/// chunk boundary and inner-grouped values past a value-eviction chunk.
const PROMPTS: [&str; 3] = [
    "a=13;b=88;c=07;d=55;e=21;f=99;g=42;h=10;?a=",
    "i=64;j=27;a=83;b=19;c=70;?c=",
    "d=01;e=02;f=03;?d=",
];
const DECODE_STEPS: usize = 44;

fn engine_for(tag: &str, cfg: MethodConfig, mode: PipelineMode, workers: usize) -> Engine {
    let dir = write_fake_artifacts(tag, '7');
    let manifest = Manifest::load(&dir).expect("fake manifest");
    let mut engine = Engine::new(manifest, cfg).expect("engine");
    engine.set_workers(workers);
    engine.set_pipeline(mode);
    engine
}

/// Prefill the three prompts and decode `DECODE_STEPS` greedy steps as one
/// batch, returning every step's logits bit patterns plus the final
/// serialized caches.
fn run_session(engine: &Engine) -> (Vec<Vec<u32>>, Vec<Vec<u8>>) {
    let mut seqs: Vec<_> = PROMPTS
        .iter()
        .map(|p| {
            let tokens = engine.manifest.encode(p).expect("prompt encodes");
            engine.prefill(&tokens).expect("prefill")
        })
        .collect();
    let mut logit_bits: Vec<Vec<u32>> = Vec::with_capacity(DECODE_STEPS);
    for _ in 0..DECODE_STEPS {
        let next: Vec<i32> = seqs.iter().map(|s| Engine::argmax(&s.last_logits)).collect();
        {
            let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
            engine.decode_step(&mut refs, &next).expect("decode step");
        }
        let step_bits: Vec<u32> = seqs
            .iter()
            .flat_map(|s| s.last_logits.iter().map(|v| v.to_bits()))
            .collect();
        logit_bits.push(step_bits);
    }
    let cache_bytes = seqs.iter().map(snapshot_sequence).collect();
    (logit_bits, cache_bytes)
}

#[test]
fn overlap_decode_is_byte_identical_to_barrier_across_the_matrix() {
    let mut case = 0usize;
    for grouping in [Grouping::Inner, Grouping::Outer] {
        for mode in [Mode::Sym, Mode::Asym, Mode::Hybrid] {
            case += 1;
            let cfg = small_window_cfg(grouping, mode);
            let tag = format!("pipe_ref_{case}");
            let reference = run_session(&engine_for(&tag, cfg, PipelineMode::Barrier, 1));
            for pipeline in [PipelineMode::Barrier, PipelineMode::Overlap] {
                for workers in [1usize, 2, 4, 8] {
                    if pipeline == PipelineMode::Barrier && workers == 1 {
                        continue; // that is the reference itself
                    }
                    let tag = format!("pipe_{case}_{}_{workers}", pipeline.name());
                    let engine = engine_for(&tag, cfg, pipeline, workers);
                    let got = run_session(&engine);
                    assert_eq!(
                        got.0,
                        reference.0,
                        "{grouping:?}/{mode:?} {} workers={workers}: logits diverged",
                        pipeline.name()
                    );
                    assert_eq!(
                        got.1,
                        reference.1,
                        "{grouping:?}/{mode:?} {} workers={workers}: cache bytes diverged",
                        pipeline.name()
                    );
                }
            }
        }
    }
}

/// Cross-ISA leg of the byte-identity contract: the same pipeline session —
/// logits bit patterns and serialized cache bytes — must be byte-identical
/// under every kernel dispatch arm the host supports. This is the in-process
/// equivalent of CI's `INNERQ_ISA=scalar` second test pass, pinning each arm
/// via `dispatch::set_active` instead of the environment.
#[test]
fn decode_pipeline_is_byte_identical_across_dispatch_arms() {
    use innerq::kernels::dispatch::{self, Isa};

    // Restore auto-detection even if an assert below panics, so a failure
    // here cannot leave the whole test process pinned to one arm.
    struct Unpin;
    impl Drop for Unpin {
        fn drop(&mut self) {
            let _ = dispatch::set_active(None);
        }
    }
    let _unpin = Unpin;

    for grouping in [Grouping::Inner, Grouping::Outer] {
        let cfg = small_window_cfg(grouping, Mode::Hybrid);
        dispatch::set_active(Some(Isa::Scalar)).expect("scalar always pins");
        let tag = format!("pipe_isa_{grouping:?}_scalar");
        let reference = run_session(&engine_for(&tag, cfg, PipelineMode::Overlap, 2));
        for isa in dispatch::supported() {
            if isa == Isa::Scalar {
                continue;
            }
            dispatch::set_active(Some(isa)).expect("supported arm pins");
            let tag = format!("pipe_isa_{grouping:?}_{isa}");
            let got = run_session(&engine_for(&tag, cfg, PipelineMode::Overlap, 2));
            assert_eq!(
                got.0, reference.0,
                "{grouping:?} {isa}: logits diverged from the scalar arm"
            );
            assert_eq!(
                got.1, reference.1,
                "{grouping:?} {isa}: cache bytes diverged from the scalar arm"
            );
        }
    }
}

/// Restore with every window frame missing: the quantized middle comes from
/// the frames, the windows from a recompute pass — and the result must be
/// bit-identical to a never-offloaded sequence, before and during decode.
#[test]
fn partial_restore_rebuilds_windows_bit_identically() {
    for grouping in [Grouping::Inner, Grouping::Outer] {
        let cfg = small_window_cfg(grouping, Mode::Hybrid);
        let tag = format!("pipe_partial_{grouping:?}");
        let engine = engine_for(&tag, cfg, PipelineMode::Overlap, 2);
        let tokens = engine.manifest.encode(PROMPTS[0]).expect("encode");
        let twin = engine.prefill(&tokens).expect("twin prefill");
        let victim = engine.prefill(&tokens).expect("victim prefill");

        let frames = snapshot_sequence_frames(&victim);
        let layers: Vec<(&[u8], Option<&[u8]>)> =
            frames.layers.iter().map(|l| (l.core.as_slice(), None)).collect();
        let (mut restored, missing) =
            restore_sequence_frames(&frames.meta, &layers).expect("partial restore");
        assert_eq!(missing.len(), frames.layers.len(), "every window frame was withheld");
        engine.rebuild_windows(&mut restored, &missing).expect("window rebuild");
        assert_eq!(
            snapshot_sequence(&restored),
            snapshot_sequence(&twin),
            "{grouping:?}: rebuilt sequence must be bit-identical to the never-offloaded twin"
        );

        // And it must *stay* identical through real decode traffic.
        let mut a = restored;
        let mut b = twin;
        for _ in 0..DECODE_STEPS {
            let ta = Engine::argmax(&a.last_logits);
            let tb = Engine::argmax(&b.last_logits);
            assert_eq!(ta, tb);
            engine.decode_step(&mut [&mut a], &[ta]).expect("decode a");
            engine.decode_step(&mut [&mut b], &[tb]).expect("decode b");
            let ba: Vec<u32> = a.last_logits.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = b.last_logits.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ba, bb, "{grouping:?}: post-restore decode diverged");
        }
        assert_eq!(snapshot_sequence(&a), snapshot_sequence(&b));
    }
}

/// The same contract end-to-end through the warm tier: a resident whose
/// window frames are evicted under pressure restores partial, and the
/// rebuilt sequence matches the original bit-for-bit.
#[test]
fn tier_pressure_evicts_windows_and_restore_recomputes_them() {
    let cfg = small_window_cfg(Grouping::Inner, Mode::Sym);
    let engine = engine_for("pipe_tier", cfg, PipelineMode::Overlap, 1);
    let tokens = engine.manifest.encode(PROMPTS[0]).expect("encode");
    let seq = engine.prefill(&tokens).expect("prefill");
    let frames = snapshot_sequence_frames(&seq);

    // Size the tier so the full frame set fits but a subsequent insert
    // forces the window frames out (1 KiB segments).
    let mut parts: Vec<(&[u8], FrameKind)> = vec![(frames.meta.as_slice(), FrameKind::Required)];
    for lf in &frames.layers {
        parts.push((lf.core.as_slice(), FrameKind::Required));
        parts.push((lf.windows.as_slice(), FrameKind::Droppable));
    }
    let seg = 1024usize;
    let segs_for = |len: usize| (len + seg - 1) / seg + usize::from(len == 0);
    let full_segs: usize = parts.iter().map(|(p, _)| segs_for(p.len()).max(1)).sum();
    let mut tier = WarmTier::new(full_segs * seg, seg);
    let receipt = tier.insert_frames(1, 1, &parts).expect("insert");
    assert_eq!(receipt.dropped_frames, 0);

    // A second required-only insert the size of the window frames squeezes
    // resident 1 down to its cores.
    let win_bytes: usize = frames.layers.iter().map(|l| l.windows.len()).sum();
    let filler = vec![0xAAu8; win_bytes.max(seg)];
    assert!(tier.insert(2, 1, &filler).is_some(), "filler insert must fit by dropping windows");
    assert!(tier.contains(1) && tier.is_partial(1), "resident 1 must survive as partial");

    let taken = tier.take_frames(1).expect("partial take");
    assert!(!taken.is_full());
    let meta = taken.frames[0].as_deref().expect("meta survives");
    let layers: Vec<(&[u8], Option<&[u8]>)> = taken.frames[1..]
        .chunks(2)
        .map(|pair| (pair[0].as_deref().expect("core survives"), pair[1].as_deref()))
        .collect();
    let (mut restored, missing) = restore_sequence_frames(meta, &layers).expect("restore");
    assert!(!missing.is_empty(), "at least one window frame must have been evicted");
    engine.rebuild_windows(&mut restored, &missing).expect("rebuild");
    assert_eq!(
        snapshot_sequence(&restored),
        snapshot_sequence(&seq),
        "tier-evicted windows must rebuild bit-identically"
    );
}

// ---------------------------------------------------------------------------
// Shared-prefix (CoW prefix store) bit-exactness contract.
// ---------------------------------------------------------------------------

/// All three prompts open with the same session context, so under the prefix
/// store the first prefill publishes one image set and the other two borrow
/// it. 30 chars = 30 tokens (the fake tokenizer is 1:1, no BOS).
const SHARED_PREFIX: &str = "a=13;b=88;c=07;d=55;e=21;f=99;";
const SHARED_SUFFIXES: [&str; 3] = ["g=42;h=10;?a=", "i=64;j=27;?c=", "?e="];

/// Prefill the three shared-prefix prompts (through the store when one is
/// given, else the private split-norm path) and decode `DECODE_STEPS` greedy
/// steps as one batch. Returns the prefill outcomes, every step's logits bit
/// patterns, and the final serialized caches.
fn run_shared_session(
    engine: &Engine,
    mut store: Option<&mut PrefixStore>,
) -> (Vec<PrefixOutcome>, Vec<Vec<u32>>, Vec<Vec<u8>>) {
    let mut outcomes = Vec::with_capacity(SHARED_SUFFIXES.len());
    let mut seqs: Vec<_> = SHARED_SUFFIXES
        .iter()
        .map(|s| {
            let prompt = format!("{SHARED_PREFIX}{s}");
            let tokens = engine.manifest.encode(&prompt).expect("prompt encodes");
            let (seq, outcome) = engine
                .prefill_shared(&tokens, SHARED_PREFIX.len(), store.as_deref_mut())
                .expect("shared prefill");
            outcomes.push(outcome);
            seq
        })
        .collect();
    let mut logit_bits: Vec<Vec<u32>> = Vec::with_capacity(DECODE_STEPS);
    for _ in 0..DECODE_STEPS {
        let next: Vec<i32> = seqs.iter().map(|s| Engine::argmax(&s.last_logits)).collect();
        {
            let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
            engine.decode_step(&mut refs, &next).expect("decode step");
        }
        let step_bits: Vec<u32> = seqs
            .iter()
            .flat_map(|s| s.last_logits.iter().map(|v| v.to_bits()))
            .collect();
        logit_bits.push(step_bits);
    }
    let cache_bytes = seqs.iter().map(snapshot_sequence).collect();
    (outcomes, logit_bits, cache_bytes)
}

/// The tentpole's core contract: decoding against a *borrowed* quantized
/// prefix must be byte-identical — logits bit patterns and serialized cache
/// bytes — to decoding against a privately-owned copy, across quantization
/// layouts (inner/outer × sym/asym/hybrid) and worker counts {1, 2, 4, 8}.
/// Sharing may only change accounting, never output bytes.
#[test]
fn shared_prefix_decode_matches_private_across_the_matrix() {
    let mut case = 0usize;
    for grouping in [Grouping::Inner, Grouping::Outer] {
        for mode in [Mode::Sym, Mode::Asym, Mode::Hybrid] {
            case += 1;
            let cfg = small_window_cfg(grouping, mode);
            let tag = format!("share_ref_{case}");
            let engine = engine_for(&tag, cfg, PipelineMode::Overlap, 1);
            let (ref_outcomes, ref_logits, ref_bytes) = run_shared_session(&engine, None);
            assert!(
                ref_outcomes.iter().all(|o| *o == PrefixOutcome::Private),
                "no store given: every prefill must stay private"
            );
            for workers in [1usize, 2, 4, 8] {
                // Share off: private split-norm path, varying workers.
                let tag = format!("share_{case}_off_{workers}");
                let engine = engine_for(&tag, cfg, PipelineMode::Overlap, workers);
                let (_, logits, bytes) = run_shared_session(&engine, None);
                assert_eq!(
                    logits, ref_logits,
                    "{grouping:?}/{mode:?} share=off workers={workers}: logits diverged"
                );
                assert_eq!(
                    bytes, ref_bytes,
                    "{grouping:?}/{mode:?} share=off workers={workers}: cache bytes diverged"
                );

                // Share on: first prefill publishes, the rest borrow.
                let tag = format!("share_{case}_on_{workers}");
                let engine = engine_for(&tag, cfg, PipelineMode::Overlap, workers);
                let mut store = PrefixStore::new(64 << 20);
                let (outcomes, logits, bytes) = run_shared_session(&engine, Some(&mut store));
                assert!(
                    matches!(outcomes[0], PrefixOutcome::Published { .. }),
                    "{grouping:?}/{mode:?} workers={workers}: first prefill must publish, got {:?}",
                    outcomes[0]
                );
                for (i, o) in outcomes.iter().enumerate().skip(1) {
                    assert!(
                        matches!(o, PrefixOutcome::Hit { .. }),
                        "{grouping:?}/{mode:?} workers={workers}: prefill {i} must hit, got {o:?}"
                    );
                }
                let dims = &engine.manifest.model;
                assert_eq!(
                    store.n_images(),
                    dims.n_layers * dims.n_kv_heads,
                    "dedup: exactly one image per (layer, head) regardless of request count"
                );
                assert_eq!(
                    logits, ref_logits,
                    "{grouping:?}/{mode:?} share=on workers={workers}: logits diverged"
                );
                assert_eq!(
                    bytes, ref_bytes,
                    "{grouping:?}/{mode:?} share=on workers={workers}: cache bytes diverged"
                );
            }
        }
    }
}

/// The restore leg of the contract: a shared-prefix sequence offloaded with
/// *by-reference* frames (prefix hashes instead of prefix bytes), squeezed
/// through warm-tier pressure that evicts its window frames, must restore —
/// resolving the prefix through the store, recomputing the windows — to a
/// sequence bit-identical to its never-offloaded twin, and keep decoding
/// bit-identically.
#[test]
fn shared_prefix_restore_through_tier_pressure_is_bit_identical() {
    for grouping in [Grouping::Inner, Grouping::Outer] {
        let cfg = small_window_cfg(grouping, Mode::Hybrid);
        let tag = format!("share_tier_{grouping:?}");
        let engine = engine_for(&tag, cfg, PipelineMode::Overlap, 2);
        let mut store = PrefixStore::new(64 << 20);
        let prompt = format!("{SHARED_PREFIX}{}", SHARED_SUFFIXES[0]);
        let tokens = engine.manifest.encode(&prompt).expect("encode");
        let base = prefix_base_hash(&cfg, &tokens[..SHARED_PREFIX.len()]);

        let (twin, first) = engine
            .prefill_shared(&tokens, SHARED_PREFIX.len(), Some(&mut store))
            .expect("twin prefill");
        assert!(matches!(first, PrefixOutcome::Published { .. }));
        let (victim, second) = engine
            .prefill_shared(&tokens, SHARED_PREFIX.len(), Some(&mut store))
            .expect("victim prefill");
        assert!(matches!(second, PrefixOutcome::Hit { .. }));

        // By-ref frames: the prefix travels as hashes, not bytes.
        let frames = snapshot_sequence_frames_by_ref(&victim, base);

        // Same pressure mechanics as the private tier test: size the tier so
        // the full frame set fits, then squeeze the windows out.
        let mut parts: Vec<(&[u8], FrameKind)> =
            vec![(frames.meta.as_slice(), FrameKind::Required)];
        for lf in &frames.layers {
            parts.push((lf.core.as_slice(), FrameKind::Required));
            parts.push((lf.windows.as_slice(), FrameKind::Droppable));
        }
        let seg = 1024usize;
        let segs_for = |len: usize| (len + seg - 1) / seg + usize::from(len == 0);
        let full_segs: usize = parts.iter().map(|(p, _)| segs_for(p.len()).max(1)).sum();
        let mut tier = WarmTier::new(full_segs * seg, seg);
        let receipt = tier.insert_frames(1, 1, &parts).expect("insert");
        assert_eq!(receipt.dropped_frames, 0);
        let win_bytes: usize = frames.layers.iter().map(|l| l.windows.len()).sum();
        let filler = vec![0xAAu8; win_bytes.max(seg)];
        assert!(tier.insert(2, 1, &filler).is_some(), "filler must fit by dropping windows");
        assert!(tier.contains(1) && tier.is_partial(1), "resident must survive as partial");

        let taken = tier.take_frames(1).expect("partial take");
        let meta = taken.frames[0].as_deref().expect("meta survives");
        let layers: Vec<(&[u8], Option<&[u8]>)> = taken.frames[1..]
            .chunks(2)
            .map(|pair| (pair[0].as_deref().expect("core survives"), pair[1].as_deref()))
            .collect();
        let (mut restored, missing) =
            restore_sequence_frames_with(meta, &layers, &|e| store.image(e))
                .expect("by-ref restore resolves through the store");
        assert!(!missing.is_empty(), "window frames must have been evicted");
        engine.rebuild_windows(&mut restored, &missing).expect("rebuild");
        assert_eq!(
            snapshot_sequence(&restored),
            snapshot_sequence(&twin),
            "{grouping:?}: by-ref restored sequence must match the never-offloaded twin"
        );

        let mut a = restored;
        let mut b = twin;
        for _ in 0..DECODE_STEPS {
            let ta = Engine::argmax(&a.last_logits);
            let tb = Engine::argmax(&b.last_logits);
            assert_eq!(ta, tb, "{grouping:?}: post-restore argmax diverged");
            engine.decode_step(&mut [&mut a], &[ta]).expect("decode a");
            engine.decode_step(&mut [&mut b], &[tb]).expect("decode b");
            let ba: Vec<u32> = a.last_logits.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = b.last_logits.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ba, bb, "{grouping:?}: post-restore decode diverged");
        }
        assert_eq!(snapshot_sequence(&a), snapshot_sequence(&b));
    }
}
