//! Tiered-store and offload-preemption tests: the snapshot bit-identity
//! matrix over every quantized segment variant, and the scheduler's
//! offload/restore life-cycle over the fake-model artifacts (preempt →
//! warm-tier residency → restore → identical completion; tier loss →
//! recompute fallback; warm deadline expiry; replay byte-identity across
//! worker counts with offloads in the stream).

use innerq::cache::store::{
    restore_head, restore_sequence_frames, snapshot_head, snapshot_sequence_frames,
};
use innerq::cache::HeadCache;
use innerq::coordinator::{Engine, Policy, Preemption, Priority, Request, SchedEvent, Scheduler};
use innerq::quant::group::Mode;
use innerq::quant::Grouping;
use innerq::runtime::Manifest;
use innerq::util::fakemodel::write_fake_artifacts;
use innerq::util::ptest::normal_vec;
use innerq::util::rng::Rng;
use innerq::workload::replay::{replay, CostModel, ReplayReport};
use innerq::workload::trace::{generate_timed, Arrival, TimedTraceConfig};
use innerq::QuantMethod;

// ---------------------------------------------------------------------------
// snapshot round-trip matrix
// ---------------------------------------------------------------------------

/// bits x sym/asym/hybrid x inner/outer grouping x tail lengths: the
/// restored cache must equal the original exactly (the `PartialEq` from the
/// PR-2 determinism work compares codes, params, planar planes, windows, and
/// norms), re-serialize to the identical bytes, and keep decoding
/// bit-identically to a cache that was never snapshotted.
#[test]
fn snapshot_matrix_round_trips_every_quantized_variant() {
    let d_h = 64;
    // w_sink + w_recent = 128 for the InnerQ base config: lengths below span
    // window-only caches, the eviction boundary, and ragged quantized tails.
    let lengths = [40usize, 128, 131, 160, 223];
    let mut seed = 0x0ff1_0ad5u64;
    for bits in [2u8, 3, 4] {
        for mode in [Mode::Sym, Mode::Asym, Mode::Hybrid] {
            for grouping in [Grouping::Inner, Grouping::Outer] {
                for &n in &lengths {
                    seed += 1;
                    let mut cfg = QuantMethod::InnerQBase.config();
                    cfg.key_bits = bits;
                    cfg.val_bits = bits;
                    cfg.key_mode = mode;
                    cfg.val_mode = mode;
                    cfg.key_grouping = grouping;
                    cfg.val_grouping = grouping;
                    // Key norm is an InnerQ (inner-grouping) feature; leave
                    // it on there so the norm vector rides the snapshot.
                    cfg.key_norm = grouping == Grouping::Inner;
                    let tag = format!("bits={bits} {mode:?} {grouping:?} n={n}");

                    let mut rng = Rng::new(seed);
                    let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
                    let vals = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
                    let mut hc = HeadCache::from_prefill(cfg, d_h, &keys, &vals);

                    let bytes = snapshot_head(&hc);
                    let mut back = restore_head(&bytes).expect(&tag);
                    assert_eq!(back, hc, "{tag}: restored != original");
                    assert_eq!(snapshot_head(&back), bytes, "{tag}: re-serialize differs");

                    // Restore-then-decode must match never-offloaded decode
                    // bit for bit: push both caches across an eviction
                    // boundary and compare the attention outputs exactly.
                    for _ in 0..37 {
                        let k = normal_vec(&mut rng, d_h, 1.0, 0.0);
                        let v = normal_vec(&mut rng, d_h, 1.0, 0.0);
                        hc.append(&k, &v);
                        back.append(&k, &v);
                    }
                    assert_eq!(back, hc, "{tag}: post-restore appends diverged");
                    let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
                    let (mut o1, mut o2) = (vec![0f32; d_h], vec![0f32; d_h]);
                    let mut scratch = Vec::new();
                    hc.attend(&q, &mut o1, &mut scratch);
                    back.attend(&q, &mut o2, &mut scratch);
                    let b1: Vec<u32> = o1.iter().map(|x| x.to_bits()).collect();
                    let b2: Vec<u32> = o2.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(b1, b2, "{tag}: restore-then-decode not bit-identical");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// snapshot portability across engine instances
// ---------------------------------------------------------------------------

/// Greedy next token (strict argmax, first max wins) — applied identically
/// to both sides of the twin comparison below.
fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// The frame snapshot format is value-based — token history, quantized
/// segments, window contents, nothing engine- or pool-local — so frames
/// written on one engine must restore on a *different* engine instance
/// (fresh PJRT stages, fresh worker pool, same `MethodConfig`), re-snapshot
/// to the identical bytes (with and without the droppable window frames,
/// exercising the destination's window-rebuild path), and keep decoding
/// bit-identically to a twin that never left its home engine. This is the
/// invariant cross-replica migration (`coordinator::fleet`) rides on.
#[test]
fn snapshot_frames_are_portable_across_engine_instances() {
    let methods = [QuantMethod::InnerQBase, QuantMethod::InnerQHybrid, QuantMethod::Kivi];
    for (mi, method) in methods.into_iter().enumerate() {
        for drop_windows in [false, true] {
            let tag = format!("{method:?} drop_windows={drop_windows}");
            // Shrink the fp windows so the prompt spills into quantized
            // segments and the core frames carry real payload.
            let mut cfg = method.config();
            cfg.w_sink = cfg.w_sink.min(4);
            cfg.w_recent = cfg.w_recent.min(8).max(4);
            let dir_a = write_fake_artifacts(&format!("port_a_{mi}_{drop_windows}"), '7');
            let dir_b = write_fake_artifacts(&format!("port_b_{mi}_{drop_windows}"), '7');
            let engine_a = Engine::new(Manifest::load(&dir_a).expect("manifest a"), cfg)
                .expect("engine a");
            let engine_b = Engine::new(Manifest::load(&dir_b).expect("manifest b"), cfg)
                .expect("engine b");

            let prompt = engine_a.manifest.encode("a=1;b=2;c=3;?a=").expect("encode");
            let mut twin = engine_a.prefill(&prompt).expect("prefill");
            let frames = snapshot_sequence_frames(&twin);

            let layers: Vec<(&[u8], Option<&[u8]>)> = frames
                .layers
                .iter()
                .map(|l| (l.core.as_slice(), (!drop_windows).then(|| l.windows.as_slice())))
                .collect();
            let (mut back, missing) =
                restore_sequence_frames(&frames.meta, &layers).expect(&tag);
            if drop_windows {
                assert!(!missing.is_empty(), "{tag}: dropped windows must be reported");
                engine_b.rebuild_windows(&mut back, &missing).expect(&tag);
            } else {
                assert!(missing.is_empty(), "{tag}: nothing should be missing");
            }
            // Re-snapshot on the destination: byte-identical frames, window
            // rebuild included (it re-runs the same deterministic prefill
            // stages the original windows came from).
            assert_eq!(
                snapshot_sequence_frames(&back),
                frames,
                "{tag}: re-snapshot on the destination engine differs"
            );

            // Continued decode must not see the move: step both sequences
            // greedily on their own engines and compare bit-exactly.
            for _ in 0..6 {
                let ta = argmax(&twin.last_logits);
                let tb = argmax(&back.last_logits);
                assert_eq!(ta, tb, "{tag}: greedy continuation diverged");
                engine_a.decode_step(&mut [&mut twin], &[ta]).expect(&tag);
                engine_b.decode_step(&mut [&mut back], &[tb]).expect(&tag);
            }
            let bits = |s: &innerq::coordinator::Sequence| {
                s.last_logits.iter().map(|x| x.to_bits()).collect::<Vec<u32>>()
            };
            assert_eq!(bits(&twin), bits(&back), "{tag}: post-restore decode diverged");
            assert_eq!(twin.tokens, back.tokens, "{tag}: token histories diverged");
        }
    }
}

// ---------------------------------------------------------------------------
// scheduler life-cycle over the fake model
// ---------------------------------------------------------------------------

fn fake_scheduler(tag: &str, budget: usize, policy: Policy, mode: Preemption) -> Scheduler {
    let dir = write_fake_artifacts(tag, '7');
    let manifest = Manifest::load(&dir).expect("fake manifest");
    let engine = Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
    let mut sched = Scheduler::new(engine, budget);
    sched.set_policy(policy);
    sched.set_preemption(mode);
    sched.set_warm_budget(1 << 20);
    sched
}

fn req_class(id: u64, prompt: &str, max_new_tokens: usize, p: Priority) -> Request {
    let mut r = Request::new(id, prompt, max_new_tokens);
    r.priority = p;
    r
}

/// Budget 6000 fits exactly one est-4608 sequence (7-char prompt + 2 new
/// tokens at the fake geometry): an arriving interactive request preempts
/// the live batch sequence; under offload the victim must take a warm-tier
/// residency, be restored without a second prefill, and complete exactly
/// like its recompute twin.
#[test]
fn offload_preemption_restores_instead_of_reprefilling() {
    let run = |tag: &str, mode: Preemption| {
        let mut sched = fake_scheduler(tag, 6000, Policy::Slo, mode);
        sched.record_events(true);
        sched.submit(req_class(1, "a=1;?a=", 2, Priority::Batch));
        sched.tick().unwrap(); // batch live
        sched.submit(req_class(2, "b=2;?b=", 2, Priority::Interactive));
        let done = sched.run_to_completion().unwrap();
        let events = sched.take_events();
        (done, events, sched)
    };

    let (off_done, off_events, off_sched) = run("offload_basic", Preemption::Offload);
    assert_eq!(off_done.len(), 2);
    for c in &off_done {
        assert_eq!(c.text, "77", "req {}: '{}'", c.id, c.text);
        assert!(c.error.is_none());
    }
    assert_eq!(off_done.first().unwrap().id, 2, "interactive completes first");
    assert_eq!(off_sched.metrics.preemptions, 1);
    assert_eq!(off_sched.metrics.offloads, 1, "victim must be offloaded, not discarded");
    assert_eq!(off_sched.metrics.restores, 1, "victim must be restored, not re-prefilled");
    assert_eq!(off_sched.metrics.offload_lost, 0);
    assert!(off_sched.metrics.offload_bytes > 0);
    assert_eq!(
        off_sched.metrics.offload_bytes, off_sched.metrics.restore_bytes,
        "restore must read back exactly what offload wrote"
    );
    assert_eq!(off_sched.tier.n_residents(), 0, "restore must clear the residency");
    assert_eq!(off_sched.tier.stats.hits, 1);

    // The events stream shows the offload life-cycle, and the victim is
    // admitted (prefilled) exactly once.
    assert!(off_events
        .iter()
        .any(|e| matches!(e, SchedEvent::Offloaded { id: 1, bytes } if *bytes > 0)));
    assert!(off_events
        .iter()
        .any(|e| matches!(e, SchedEvent::Restored { id: 1, bytes } if *bytes > 0)));
    let admits_of_1 = off_events
        .iter()
        .filter(|e| matches!(e, SchedEvent::Admitted { id: 1, .. }))
        .count();
    assert_eq!(admits_of_1, 1, "a restored sequence must not prefill again");

    // Recompute twin: same trace, same completions — offload only changes
    // the cost of getting there.
    let (rec_done, rec_events, rec_sched) = run("offload_vs_recompute", Preemption::Recompute);
    assert_eq!(rec_sched.metrics.offloads, 0);
    assert!(rec_events.iter().any(|e| matches!(e, SchedEvent::Preempted { id: 1 })));
    let key = |d: &[innerq::coordinator::Completion]| {
        d.iter().map(|c| (c.id, c.text.clone(), c.n_generated)).collect::<Vec<_>>()
    };
    assert_eq!(key(&off_done), key(&rec_done));
}

/// A snapshot evicted from the warm tier while its owner waits is terminal:
/// readmission must fall back to a recompute-style re-prefill (offload-lost)
/// and still complete correctly.
#[test]
fn evicted_snapshot_falls_back_to_recompute() {
    let mut sched = fake_scheduler("offload_lost", 6000, Policy::Slo, Preemption::Offload);
    sched.record_events(true);
    sched.submit(req_class(1, "a=1;?a=", 2, Priority::Batch));
    sched.tick().unwrap();
    sched.submit(req_class(2, "b=2;?b=", 2, Priority::Interactive));
    sched.tick().unwrap(); // preempts + offloads id 1
    assert_eq!(sched.metrics.offloads, 1);
    assert!(sched.tier.contains(1));
    // Simulate the tier dropping the resident (what LRU eviction does when
    // a more recent snapshot needs the segments).
    assert!(sched.tier.remove(1));
    let done = sched.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);
    for c in &done {
        assert_eq!(c.text, "77");
        assert!(c.error.is_none());
    }
    assert_eq!(sched.metrics.offload_lost, 1);
    assert_eq!(sched.metrics.restores, 0);
    let events = sched.take_events();
    assert!(events.iter().any(|e| matches!(e, SchedEvent::OffloadLost { id: 1 })));
    let admits_of_1 = events
        .iter()
        .filter(|e| matches!(e, SchedEvent::Admitted { id: 1, .. }))
        .count();
    assert_eq!(admits_of_1, 2, "lost snapshot forces a second prefill");
}

/// Deadlines keep counting while a request sits in the warm tier; expiry
/// there must be terminal and must free the tier residency.
#[test]
fn warm_resident_deadline_expires_and_frees_the_tier() {
    let mut sched = fake_scheduler("offload_expire", 6000, Policy::Slo, Preemption::Offload);
    let mut victim = req_class(1, "a=1;?a=", 2, Priority::Batch);
    victim.deadline_us = Some(50_000);
    sched.submit(victim);
    sched.tick().unwrap();
    sched.submit(req_class(2, "b=2;?b=", 2, Priority::Interactive));
    sched.tick().unwrap(); // offloads id 1
    assert!(sched.tier.contains(1));
    sched.set_now(100_000);
    let done = sched.run_to_completion().unwrap();
    let expired = done.iter().find(|c| c.id == 1).unwrap();
    assert!(expired.error.as_deref().unwrap_or("").contains("deadline"));
    assert_eq!(sched.metrics.expired, 1);
    assert_eq!(sched.tier.n_residents(), 0, "expiry must free the residency");
    let ok = done.iter().find(|c| c.id == 2).unwrap();
    assert!(ok.error.is_none());
}

// ---------------------------------------------------------------------------
// replay determinism with offloads in the stream
// ---------------------------------------------------------------------------

fn offload_replay(tag: &str, workers: usize) -> ReplayReport {
    let trace = generate_timed(&TimedTraceConfig {
        n_requests: 48,
        arrival: Arrival::Poisson { rate_rps: 2000.0 },
        priority_mix: [1.0, 2.0, 1.0],
        seed: 42,
        ..TimedTraceConfig::default()
    });
    let dir = write_fake_artifacts(tag, '7');
    let manifest = Manifest::load(&dir).expect("fake manifest");
    let mut engine = Engine::new(manifest, QuantMethod::InnerQBase.config()).expect("engine");
    engine.set_workers(workers);
    let mut sched = Scheduler::new(engine, 64_000);
    sched.set_policy(Policy::Slo);
    sched.set_preemption(Preemption::Offload);
    sched.set_warm_budget(1 << 20);
    replay(&mut sched, &trace, &CostModel::default()).expect("replay")
}

#[test]
fn offload_replay_is_byte_identical_across_worker_counts() {
    let a = offload_replay("off_det_w1", 1);
    assert!(
        a.metrics.preemptions > 0 && a.metrics.offloads > 0,
        "overloaded trace must exercise offload preemption \
         (preemptions {}, offloads {})",
        a.metrics.preemptions,
        a.metrics.offloads
    );
    assert!(a.metrics.restores > 0, "at least one victim must be restored");
    let b = offload_replay("off_det_w4", 4);
    assert_eq!(
        a.to_json().dump(),
        b.to_json().dump(),
        "offload-mode replay diverged between workers=1 and workers=4"
    );
}
