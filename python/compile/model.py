"""Layer-2: the tiny-Llama model (RMSNorm + RoPE + GQA + SwiGLU).

Two views of the same parameters:

* `forward` — full causal forward pass used for build-time training and as
  the numerical reference;
* staged functions (`embed_fn`, `qkv_fn`, `attn_out_fn`, `lm_head_fn`,
  `prefill_fn`) — the decode pipeline cut exactly where the Rust coordinator
  owns the quantized-cache attention (Eq. 3-5 + Fig. 2 merge live in Rust).
  `aot.py` lowers each stage to an HLO-text artifact with the weights baked
  in as constants.

The L1 Pallas kernels enter through `quant_attention_fn`, a fixed-shape
quantized-cache attention stage composed from `kernels.innerq` — exported as
its own artifact to prove all three layers lower into one executable (see
DESIGN.md; the Rust native kernels remain the primary hot path because the
cache is dynamically shaped).
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .kernels import innerq, ref


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = corpus.vocab_size()
    d_model: int = 128
    n_layers: int = 3
    n_q_heads: int = 4
    n_kv_heads: int = 2
    d_h: int = 32
    d_ff: int = 256
    rope_theta: float = 10000.0

    @property
    def q_dim(self):
        return self.n_q_heads * self.d_h

    @property
    def kv_dim(self):
        return self.n_kv_heads * self.d_h


def init_params(cfg: ModelConfig, key):
    """Glorot-ish init, a dict-of-dicts pytree."""
    ks = jax.random.split(key, 2 + cfg.n_layers)

    def dense(k, fan_in, fan_out):
        return jax.random.normal(k, (fan_in, fan_out), jnp.float32) / np.sqrt(fan_in)

    params = {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02,
        "head": dense(ks[1], cfg.d_model, cfg.vocab),
        "final_norm": jnp.ones(cfg.d_model),
        "layers": [],
    }
    for l in range(cfg.n_layers):
        lk = jax.random.split(ks[2 + l], 7)
        params["layers"].append({
            "attn_norm": jnp.ones(cfg.d_model),
            "wq": dense(lk[0], cfg.d_model, cfg.q_dim),
            "wk": dense(lk[1], cfg.d_model, cfg.kv_dim),
            "wv": dense(lk[2], cfg.d_model, cfg.kv_dim),
            "wo": dense(lk[3], cfg.q_dim, cfg.d_model),
            "mlp_norm": jnp.ones(cfg.d_model),
            "w_gate": dense(lk[4], cfg.d_model, cfg.d_ff),
            "w_up": dense(lk[5], cfg.d_model, cfg.d_ff),
            "w_down": dense(lk[6], cfg.d_ff, cfg.d_model),
        })
    return params


def rmsnorm(x, w, eps=1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def rope(x, positions, theta):
    """Rotary embedding. x: (..., n_heads, d_h); positions: (...,) int32."""
    d_h = x.shape[-1]
    half = d_h // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv(cfg, layer, h, positions):
    """RMSNorm + QKV projection + RoPE for one layer.

    h: (..., d_model); positions: (...,). Returns q (..., n_q, d_h),
    k/v (..., n_kv, d_h).
    """
    x = rmsnorm(h, layer["attn_norm"])
    q = (x @ layer["wq"]).reshape(*x.shape[:-1], cfg.n_q_heads, cfg.d_h)
    k = (x @ layer["wk"]).reshape(*x.shape[:-1], cfg.n_kv_heads, cfg.d_h)
    v = (x @ layer["wv"]).reshape(*x.shape[:-1], cfg.n_kv_heads, cfg.d_h)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_out(layer, h, ctx_flat):
    """Residual add of the attention output + the MLP block."""
    h = h + ctx_flat @ layer["wo"]
    x = rmsnorm(h, layer["mlp_norm"])
    return h + (jax.nn.silu(x @ layer["w_gate"]) * (x @ layer["w_up"])) @ layer["w_down"]


def forward(cfg: ModelConfig, params, tokens):
    """Full causal forward. tokens: (B, L) int32 -> logits (B, L, vocab)."""
    B, L = tokens.shape
    h = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(L), (B, L))
    mask = jnp.tril(jnp.ones((L, L), bool))
    rep = cfg.n_q_heads // cfg.n_kv_heads
    for layer in params["layers"]:
        q, k, v = _qkv(cfg, layer, h, positions)  # (B, L, heads, d_h)
        kq = jnp.repeat(k, rep, axis=2)
        vq = jnp.repeat(v, rep, axis=2)
        s = jnp.einsum("blhd,bmhd->bhlm", q, kq) / np.sqrt(cfg.d_h)
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhlm,bmhd->blhd", p, vq).reshape(B, L, cfg.q_dim)
        h = _attn_out(layer, h, ctx)
    return rmsnorm(h, params["final_norm"]) @ params["head"]


# ---------------------------------------------------------------------------
# Staged decode functions (one HLO artifact each; weights baked in by aot.py)
# ---------------------------------------------------------------------------


def embed_fn(cfg, params, tokens):
    """tokens (B,) int32 -> hidden (B, d_model)."""
    return (params["embed"][tokens],)


def qkv_fn(cfg, params, l, h, positions):
    """h (B, d_model), positions (B,) -> q (B, n_q, d_h), k/v (B, n_kv, d_h)."""
    return _qkv(cfg, params["layers"][l], h, positions)


def attn_out_fn(cfg, params, l, h, ctx):
    """h (B, d_model) residual + ctx (B, q_dim) -> next hidden (B, d_model)."""
    return (_attn_out(params["layers"][l], h, ctx),)


def lm_head_fn(cfg, params, h):
    """h (B, d_model) -> logits (B, vocab)."""
    return (rmsnorm(h, params["final_norm"]) @ params["head"],)


def prefill_fn(cfg, params, tokens):
    """Full prefill for one sequence. tokens (1, L) ->
    (logits (L, vocab), ks (n_layers, L, n_kv, d_h), vs likewise).
    Padded positions are harmless under the causal mask; the Rust side
    slices K/V to the true length.
    """
    _, L = tokens.shape
    h = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(L), tokens.shape)
    ks, vs = [], []
    rep = cfg.n_q_heads // cfg.n_kv_heads
    mask = jnp.tril(jnp.ones((L, L), bool))
    for layer in params["layers"]:
        q, k, v = _qkv(cfg, layer, h, positions)
        ks.append(k[0])
        vs.append(v[0])
        kq = jnp.repeat(k, rep, axis=2)
        vq = jnp.repeat(v, rep, axis=2)
        s = jnp.einsum("blhd,bmhd->bhlm", q, kq) / np.sqrt(cfg.d_h)
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhlm,bmhd->blhd", p, vq).reshape(*tokens.shape, cfg.q_dim)
        h = _attn_out(layer, h, ctx)
    logits = rmsnorm(h, params["final_norm"])[0] @ params["head"]
    return logits, jnp.stack(ks), jnp.stack(vs)


def quant_attention_fn(cfg, n_tokens: int, bits: int = 3):
    """L1-in-L2 composition: a fixed-shape InnerQ quantized-cache attention
    stage built from the Pallas kernels, for one KV head.

    Returns a function (q (d_h,), kcodes (n, d_h/G, G) int8, kscale (n, d_h/G),
    vcodes (n/G, d_h, G) int8, vscale (n/G, d_h)) -> ctx (d_h,). Symmetric
    3-bit K / V (InnerQ_Base) so no zero inputs. Lowered by aot.py into
    `quant_attn.hlo.txt`.
    """

    def fn(q, kcodes, kscale, vcodes, vscale):
        zk = jnp.zeros_like(kscale)
        scores = innerq.qk_inner(q, kcodes, kscale, zk)
        p = jax.nn.softmax(scores / np.sqrt(cfg.d_h))
        zv = jnp.zeros_like(vscale)
        ctx = innerq.pv_inner(p, vcodes, vscale, zv)
        return (ctx,)

    return fn


def decode_reference(cfg, params, tokens, quant=None):
    """Python decode loop through the *staged* functions with an FP (or
    simulated-quantized) cache — the oracle for the Rust engine.

    tokens: (L,) prompt+continuation; returns logits at every position,
    computed autoregressively (prefill length 1: pure decode, worst case for
    the cache path). `quant`: None for FP cache or a dict like
    {"key_bits":3, "val_bits":3, "mode":"sym"} applying InnerQ-layout
    quantization to the whole cache each step (window-free simulation used
    by golden tests; the windowed policy is exercised in Rust).
    """
    L = tokens.shape[0]
    rep = cfg.n_q_heads // cfg.n_kv_heads
    caches = [{"k": [], "v": []} for _ in range(cfg.n_layers)]
    logits_all = []
    for t in range(L):
        h = embed_fn(cfg, params, tokens[t : t + 1])[0]
        pos = jnp.array([t], jnp.int32)
        for l in range(cfg.n_layers):
            q, k, v = qkv_fn(cfg, params, l, h, pos)
            caches[l]["k"].append(k[0])
            caches[l]["v"].append(v[0])
            K = jnp.stack(caches[l]["k"])  # (t+1, n_kv, d_h)
            V = jnp.stack(caches[l]["v"])
            ctx = []
            for hq in range(cfg.n_q_heads):
                kv = hq // rep
                Kh, Vh = K[:, kv], V[:, kv]
                if quant is not None and (t + 1) >= 64:
                    kq = ref.quantize_key_inner(Kh, quant["key_bits"], quant["mode"])
                    Kh = ref.dequantize_groups(kq).reshape(Kh.shape)
                    n_full = (Vh.shape[0] // 32) * 32
                    if n_full:
                        vq = ref.quantize_val_inner(Vh[:n_full], quant["val_bits"], quant["mode"])
                        Vh = jnp.concatenate(
                            [ref.dequantize_groups(vq).transpose(0, 2, 1).reshape(n_full, -1),
                             Vh[n_full:]])
                ctx.append(ref.attention_reference(q[0, hq], Kh, Vh))
            h = attn_out_fn(cfg, params, l, h, jnp.concatenate(ctx)[None])[0]
        logits_all.append(lm_head_fn(cfg, params, h)[0][0])
    return jnp.stack(logits_all)
