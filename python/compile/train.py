"""Build-time training of the tiny-Llama on the variable-recall corpus.

Runs once inside `make artifacts` (cached in artifacts/weights.npz). A few
hundred Adam steps are enough for the model to learn the grammar and most of
the recall task — what matters for the reproduction is that held-out NLL is
meaningfully sensitive to KV-cache fidelity, not SOTA accuracy.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model


def loss_fn(cfg, params, tokens):
    """Next-token cross entropy over non-pad positions."""
    logits = model.forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = targets != corpus.BOS
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8, clip=1.0):
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2**t), v)
    params = jax.tree.map(lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps), params, mh, vh)
    return params, {"m": m, "v": v, "t": t}


def train(cfg: model.ModelConfig, steps=3000, batch_size=16, seq_len=192, lr=2e-3,
          seed=0, log_every=40, init_params=None):
    """Returns (params, history) — history is [(step, train_loss)].

    `init_params`: optionally resume from existing weights (used to continue
    a cached run). LR follows a cosine decay to lr/10.
    """
    rng = np.random.default_rng(seed)
    params = init_params if init_params is not None else model.init_params(
        cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, tokens, lr_t):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(params)
        params, opt = adam_step(params, grads, opt, lr_t)
        return params, opt, loss

    history = []
    t0 = time.time()
    for i in range(steps):
        lr_t = lr * (0.55 + 0.45 * np.cos(np.pi * i / steps))
        tokens = jnp.asarray(corpus.batch(rng, batch_size, seq_len))
        params, opt, loss = step(params, opt, tokens, lr_t)
        if i % log_every == 0 or i == steps - 1:
            history.append((i, float(loss)))
            print(f"[train] step {i:4d} loss {float(loss):.4f} ({time.time()-t0:.0f}s)",
                  flush=True)
    return params, history


def flatten_params(params):
    """Flatten to {name: array} for npz round-tripping."""
    out = {"embed": params["embed"], "head": params["head"], "final_norm": params["final_norm"]}
    for l, layer in enumerate(params["layers"]):
        for k, v in layer.items():
            out[f"layer{l}/{k}"] = v
    return out


def unflatten_params(flat, n_layers):
    params = {
        "embed": jnp.asarray(flat["embed"]),
        "head": jnp.asarray(flat["head"]),
        "final_norm": jnp.asarray(flat["final_norm"]),
        "layers": [],
    }
    for l in range(n_layers):
        prefix = f"layer{l}/"
        params["layers"].append(
            {k[len(prefix):]: jnp.asarray(v) for k, v in flat.items() if k.startswith(prefix)}
        )
    return params
