"""AOT lowering: train (or load cached) weights, lower every decode stage to
HLO text, and emit the artifact manifest + golden cross-layer test vectors.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Runs once under `make artifacts`. Python never runs on the request path.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, model, train
from .kernels import ref

DECODE_BATCHES = [1, 2, 4, 8]
PREFILL_BUCKETS = [64, 128, 256, 512, 1024, 2048]
QUANT_ATTN_TOKENS = 512


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_to_file(fn, args, path):
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(path, "w") as f:
        f.write(text)
    return os.path.basename(path)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def export_decode_stages(cfg, params, out_dir):
    """One artifact per (stage, batch size)."""
    names = {}
    for B in DECODE_BATCHES:
        names[f"embed_b{B}"] = lower_to_file(
            lambda toks: model.embed_fn(cfg, params, toks),
            (spec((B,), jnp.int32),),
            f"{out_dir}/decode_embed_b{B}.hlo.txt",
        )
        for l in range(cfg.n_layers):
            names[f"qkv_l{l}_b{B}"] = lower_to_file(
                (lambda l: lambda h, pos: model.qkv_fn(cfg, params, l, h, pos))(l),
                (spec((B, cfg.d_model)), spec((B,), jnp.int32)),
                f"{out_dir}/decode_qkv_l{l}_b{B}.hlo.txt",
            )
            names[f"out_l{l}_b{B}"] = lower_to_file(
                (lambda l: lambda h, ctx: model.attn_out_fn(cfg, params, l, h, ctx))(l),
                (spec((B, cfg.d_model)), spec((B, cfg.q_dim))),
                f"{out_dir}/decode_out_l{l}_b{B}.hlo.txt",
            )
        names[f"head_b{B}"] = lower_to_file(
            lambda h: model.lm_head_fn(cfg, params, h),
            (spec((B, cfg.d_model)),),
            f"{out_dir}/decode_head_b{B}.hlo.txt",
        )
    return names


def export_prefill(cfg, params, out_dir):
    names = {}
    for L in PREFILL_BUCKETS:
        names[f"prefill_l{L}"] = lower_to_file(
            lambda toks: model.prefill_fn(cfg, params, toks),
            (spec((1, L), jnp.int32),),
            f"{out_dir}/prefill_l{L}.hlo.txt",
        )
    return names


def export_quant_attention(cfg, out_dir):
    """The L1-in-L2 artifact: Pallas InnerQ attention lowered into HLO."""
    n, d_h = QUANT_ATTN_TOKENS, cfg.d_h
    ng = d_h // 32
    fn = model.quant_attention_fn(cfg, n)
    return {
        "quant_attn": lower_to_file(
            fn,
            (
                spec((d_h,)),
                spec((n, ng, 32), jnp.int32),  # i32: the xla crate has no i8 literal ctor
                spec((n, ng)),
                spec((n // 32, d_h, 32), jnp.int32),
                spec((n // 32, d_h)),
            ),
            f"{out_dir}/quant_attn.hlo.txt",
        )
    }


def export_golden(cfg, params, out_dir):
    """Cross-layer test vectors consumed by Rust integration tests."""
    os.makedirs(f"{out_dir}/golden", exist_ok=True)
    rng = np.random.default_rng(1234)

    # 1. FP decode trace: prompt -> per-step logits through the staged path.
    tokens = corpus.sample_tokens(rng, n_assign=12, n_queries=3)[:56]
    logits = model.decode_reference(cfg, params, jnp.asarray(tokens))
    with open(f"{out_dir}/golden/decode_fp.json", "w") as f:
        json.dump(
            {
                "tokens": tokens.tolist(),
                "logits": np.asarray(logits, np.float64).round(6).tolist(),
            },
            f,
        )

    # 2. Per-stage vectors at B=1 (runtime executable smoke tests).
    h = np.asarray(model.embed_fn(cfg, params, jnp.asarray(tokens[:1]))[0])
    q, k, v = (np.asarray(a) for a in model.qkv_fn(
        cfg, params, 0, jnp.asarray(h), jnp.array([0], jnp.int32)))
    ctx = rng.standard_normal((1, cfg.q_dim)).astype(np.float32)
    h2 = np.asarray(model.attn_out_fn(cfg, params, 0, jnp.asarray(h), jnp.asarray(ctx))[0])
    head = np.asarray(model.lm_head_fn(cfg, params, jnp.asarray(h2))[0])
    with open(f"{out_dir}/golden/stages.json", "w") as f:
        json.dump(
            {
                "token": int(tokens[0]),
                "h": h.flatten().tolist(),
                "q": q.flatten().tolist(),
                "k": k.flatten().tolist(),
                "v": v.flatten().tolist(),
                "ctx": ctx.flatten().tolist(),
                "h2": h2.flatten().tolist(),
                "head": head.flatten().tolist(),
            },
            f,
        )

    # 3. Quantizer parity vectors: same matrix quantized by ref.py; Rust must
    # produce identical codes/scales (f16 rounding parity).
    mat = rng.standard_normal((64, 64)).astype(np.float32)
    mat[:, 7] *= 9.0  # an outlier channel
    out = {"matrix": mat.flatten().round(6).tolist(), "cases": []}
    for bits, mode in [(3, "sym"), (2, "asym"), (2, "hybrid")]:
        kq = ref.quantize_key_inner(jnp.asarray(mat), bits, mode)
        out["cases"].append(
            {
                "bits": bits,
                "mode": mode,
                "codes": np.asarray(kq["codes"]).flatten().tolist(),
                "scale": np.asarray(kq["scale"], np.float64).flatten().tolist(),
                "zero": np.asarray(kq["zero"], np.float64).flatten().tolist(),
                "mask": np.asarray(kq["mask"]).astype(int).flatten().tolist(),
            }
        )
    with open(f"{out_dir}/golden/quantizer.json", "w") as f:
        json.dump(out, f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=240)
    ap.add_argument("--retrain", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = model.ModelConfig()
    weights_path = f"{args.out_dir}/weights.npz"
    t0 = time.time()
    if os.path.exists(weights_path) and not args.retrain:
        print(f"[aot] loading cached weights from {weights_path}")
        flat = dict(np.load(weights_path))
        params = train.unflatten_params(flat, cfg.n_layers)
        history = json.load(open(f"{args.out_dir}/train_log.json"))
    else:
        print(f"[aot] training {cfg.n_layers}-layer d={cfg.d_model} model ...")
        params, history = train.train(cfg, steps=args.steps)
        np.savez(weights_path, **train.flatten_params(params))
        json.dump(history, open(f"{args.out_dir}/train_log.json", "w"))

    print("[aot] lowering decode stages ...")
    names = export_decode_stages(cfg, params, args.out_dir)
    print("[aot] lowering prefill buckets ...")
    names.update(export_prefill(cfg, params, args.out_dir))
    print("[aot] lowering pallas quantized-attention stage ...")
    names.update(export_quant_attention(cfg, args.out_dir))
    print("[aot] writing golden vectors ...")
    export_golden(cfg, params, args.out_dir)

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_q_heads": cfg.n_q_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "d_h": cfg.d_h,
            "d_ff": cfg.d_ff,
            "rope_theta": cfg.rope_theta,
        },
        "charset": corpus.CHARSET,
        "bos": corpus.BOS,
        "decode_batches": DECODE_BATCHES,
        "prefill_buckets": PREFILL_BUCKETS,
        "quant_attn_tokens": QUANT_ATTN_TOKENS,
        "artifacts": names,
        "final_train_loss": history[-1][1],
    }
    with open(f"{args.out_dir}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done: {len(names)} artifacts in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
