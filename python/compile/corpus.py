"""Synthetic structured corpus: the variable-recall language.

Documents are streams of single-letter variable assignments with
*reassignment* (latest binding wins), followed by recall queries::

    c=41;a=07;c=93;f=22;...;?c=93;?a=07.

Predicting the two value digits after ``?x=`` requires attending back to the
latest assignment of ``x`` — a long-range dependency at a random depth in the
context, which makes held-out NLL, recall accuracy and top-1-agreement
directly sensitive to KV-cache fidelity (DESIGN.md substitutions). Document
length scales freely through the number of assignments (LongBench-shaped
evaluation uses thousands).

The Rust workload generator (`rust/src/workload/corpus.rs`) implements the
same grammar; the charset travels in the artifact manifest so both sides
tokenize identically.
"""

import numpy as np

# Token 0 is BOS/PAD. Order is part of the model contract — do not reorder.
CHARSET = "abcdefghij0123456789=;?."
BOS = 0
N_NAMES = 10


def vocab_size() -> int:
    return len(CHARSET) + 1


def encode(text: str) -> list[int]:
    idx = {c: i + 1 for i, c in enumerate(CHARSET)}
    return [idx[c] for c in text]


def decode(tokens) -> str:
    return "".join(CHARSET[t - 1] for t in tokens if t > 0)


def sample_sequence(rng: np.random.Generator, n_assign: int, n_queries: int) -> str:
    """One corpus document: `n_assign` (re)assignments, then queries."""
    values = {}
    parts = []
    for i in range(n_assign):
        # first N_NAMES assignments cover every name once (so early queries
        # are always answerable); later ones reassign at random.
        name = CHARSET[i % N_NAMES] if i < N_NAMES else CHARSET[rng.integers(0, N_NAMES)]
        val = f"{rng.integers(0, 100):02d}"
        values[name] = val
        parts.append(f"{name}={val};")
    names = list(values)
    for _ in range(n_queries):
        name = names[rng.integers(0, len(names))]
        parts.append(f"?{name}={values[name]};")
    return "".join(parts)[:-1] + "."


def sample_tokens(rng, n_assign, n_queries, length=None):
    """Encoded document with BOS, optionally padded/truncated to `length`."""
    toks = [BOS] + encode(sample_sequence(rng, n_assign, n_queries))
    if length is not None:
        toks = toks[:length] + [BOS] * max(0, length - len(toks))
    return np.array(toks, np.int32)


def batch(rng, batch_size, seq_len, n_assign=30, n_queries=12):
    """Training batch (B, L) of padded documents."""
    return np.stack(
        [sample_tokens(rng, n_assign, n_queries, seq_len) for _ in range(batch_size)]
    )


def query_positions(tokens) -> list[tuple[int, int]]:
    """(position, target) pairs for the value digits of recall queries:
    position p's logits must predict token p+1 ('?', name, '=', d0, d1)."""
    q = encode("?")[0]
    eq = encode("=")[0]
    out = []
    toks = list(tokens)
    i = 0
    while i < len(toks):
        if toks[i] == q and i + 4 < len(toks) and toks[i + 2] == eq:
            out.append((i + 2, toks[i + 3]))  # '=' predicts d0
            out.append((i + 3, toks[i + 4]))  # d0 predicts d1
            i += 5
        else:
            i += 1
    return out
