"""Layer-1 Pallas kernels for the KIVI (outer-grouped) baseline layout.

The contrast with `innerq.py` is the point of the paper's Figure 1: here the
scale tile for a (block_t, d_h) code tile is (d_h,)-wide *per 32-token chunk*
— every output element needs a different scale, so the kernel materializes a
hoisted q*s vector per chunk (on GPU: per-lane scale loads with no warp
reuse; on TPU: a full-lane-width scale tile per chunk instead of ng scalars
per token row).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 32


def _qk_outer_kernel(q_ref, codes_ref, scale_ref, zero_ref, o_ref):
    """One 32-token chunk of scores under per-channel (outer) grouping.

    q_ref:     (d_h,)
    codes_ref: (1, d_h, G) int8 codes, channel rows x token columns
    scale_ref: (1, d_h)    per-channel scales for this chunk
    zero_ref:  (1, d_h)    per-channel effective zero terms
    o_ref:     (G,)        scores for the chunk's tokens
    """
    q = q_ref[...]
    codes = codes_ref[0].astype(jnp.float32)       # (d_h, G)
    qs = q * scale_ref[0]                          # (d_h,) hoisted per chunk
    zacc = jnp.sum(q * zero_ref[0])
    o_ref[...] = jnp.sum(codes * qs[:, None], axis=0) + zacc


@jax.jit
def qk_outer(q, codes, scale, zero):
    """Scores over the KIVI key layout.

    q: (d_h,); codes: (C, d_h, G) int8 (chunk-major, channel rows);
    scale/zero: (C, d_h). Returns (C*G,) scores.
    """
    c, d_h, g = codes.shape
    assert g == GROUP
    return pl.pallas_call(
        _qk_outer_kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((d_h,), lambda i: (0,)),
            pl.BlockSpec((1, d_h, GROUP), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d_h), lambda i: (i, 0)),
            pl.BlockSpec((1, d_h), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((GROUP,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((c * g,), jnp.float32),
        interpret=True,
    )(q, codes, scale, zero)


def _pv_outer_kernel(p_ref, codes_ref, scale_ref, zero_ref, o_ref):
    """One token-block of context under per-token (outer) value grouping.

    p_ref:     (T,)
    codes_ref: (T, ng, G) int8 codes (token rows, channel groups)
    scale_ref: (T, ng)
    zero_ref:  (T, ng)
    o_ref:     (ng, G) accumulated context, reshaped by channel group
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    p = p_ref[...]
    codes = codes_ref[...].astype(jnp.float32)     # (T, ng, G)
    deq = codes * scale_ref[...][..., None] + zero_ref[...][..., None]
    o_ref[...] += jnp.sum(deq * p[:, None, None], axis=0)


@functools.partial(jax.jit, static_argnames=("block_t",))
def pv_outer(p, codes, scale, zero, block_t: int = 256):
    """Context over the KIVI value layout.

    p: (n,); codes: (n, d_h/G, G) int8; scale/zero: (n, d_h/G).
    Returns (d_h,) f32.
    """
    n, ng, g = codes.shape
    assert g == GROUP
    block_t = min(block_t, n)
    assert n % block_t == 0
    out = pl.pallas_call(
        _pv_outer_kernel,
        grid=(n // block_t,),
        in_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((block_t, ng, GROUP), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_t, ng), lambda i: (i, 0)),
            pl.BlockSpec((block_t, ng), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ng, GROUP), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ng, GROUP), jnp.float32),
        interpret=True,
    )(p, codes, scale, zero)
    return out.reshape(-1)


def vmem_report(n_tokens: int, d_h: int, bits: int):
    """Scale-traffic comparison vs the inner layout (DESIGN §Perf).

    For a 32-token chunk the outer key kernel streams d_h scales + d_h zeros;
    the inner key kernel streams 32*(d_h/32) = d_h scales total for the same
    32 tokens but reuses each within a contiguous group-partial accumulation
    (one FMA tail per group) — and symmetric InnerQ carries no zeros at all.
    """
    chunk_scale_loads_outer = 2 * d_h       # scales + zeros per 32 tokens
    chunk_scale_loads_inner = d_h // GROUP * GROUP  # = d_h, but no zeros (sym)
    return {
        "outer_scale_loads_per_chunk": chunk_scale_loads_outer,
        "inner_scale_loads_per_chunk": chunk_scale_loads_inner,
        "ratio": chunk_scale_loads_outer / chunk_scale_loads_inner,
    }
