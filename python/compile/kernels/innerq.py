"""Layer-1 Pallas kernels for InnerQ fused dequantize-GEMV (§4.4).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
kernels assign a thread block per cache row and reuse one scale per warp.
On TPU the same insight maps to the VPU/MXU tiling: quantization groups
running along the reduction axis mean a (block_t, d_h) VMEM tile needs only
a (block_t, d_h/32) scale tile — an 32x reduction in scale traffic — and the
group-partial accumulate-then-scale structure vectorizes along lanes.

BlockSpecs express the HBM->VMEM schedule over the token axis (the axis the
paper tiles with thread blocks). Codes are carried as int8 *logical* codes
(signed for symmetric, biased-unsigned handled on the Rust side); physical
3-bit packing is a storage-layer concern that lives in Rust — XLA/Mosaic has
no sub-byte loads, so a TPU deployment would pack into int8 lanes the same
way.

All kernels run with interpret=True: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 32


def _qk_inner_kernel(q_ref, codes_ref, scale_ref, zero_ref, o_ref):
    """One token-block of scores: group-partial dot, scale applied per group.

    q_ref:     (ng, G)        query, reshaped by group
    codes_ref: (T, ng, G)     int8 codes for T tokens
    scale_ref: (T, ng)        f32 scales (f16-rounded upstream)
    zero_ref:  (T, ng)        f32 effective zero terms (0 for symmetric)
    o_ref:     (T,)           scores
    """
    q = q_ref[...]
    codes = codes_ref[...].astype(jnp.float32)
    # group-partial accumulation: one multiply-add per element ...
    acc = jnp.sum(codes * q[None, :, :], axis=-1)  # (T, ng)
    # ... then one scale application per *group*, not per element:
    qsum = jnp.sum(q, axis=-1)  # (ng,)
    o_ref[...] = jnp.sum(acc * scale_ref[...] + zero_ref[...] * qsum[None, :], axis=-1)


@functools.partial(jax.jit, static_argnames=("block_t",))
def qk_inner(q, codes, scale, zero, block_t: int = 256):
    """Fused dequant-GEMV scores over the InnerQ key layout.

    q: (d_h,); codes: (n, d_h/G, G) int8; scale/zero: (n, d_h/G) f32.
    n must be a multiple of block_t (the cache manager pads chunks).
    Returns (n,) f32 scores.
    """
    n, ng, g = codes.shape
    assert g == GROUP and q.shape[0] == ng * g
    block_t = min(block_t, n)
    assert n % block_t == 0, f"n={n} not a multiple of block_t={block_t}"
    grid = (n // block_t,)
    return pl.pallas_call(
        _qk_inner_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ng, GROUP), lambda i: (0, 0)),          # q: resident
            pl.BlockSpec((block_t, ng, GROUP), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_t, ng), lambda i: (i, 0)),
            pl.BlockSpec((block_t, ng), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(q.reshape(ng, GROUP), codes, scale, zero)


def _pv_inner_kernel(p_ref, codes_ref, scale_ref, zero_ref, o_ref):
    """One 32-token chunk of context accumulation (channel-major codes).

    p_ref:     (1, G)       softmax weights for this chunk's tokens
    codes_ref: (1, d_h, G)  int8 codes, channel rows
    scale_ref: (1, d_h)     f32 per-channel-group scales
    zero_ref:  (1, d_h)     f32 effective zero terms
    o_ref:     (d_h,)       accumulated context (all chunks map here)
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    p = p_ref[0]
    codes = codes_ref[0].astype(jnp.float32)          # (d_h, G)
    acc = jnp.sum(codes * p[None, :], axis=-1)        # (d_h,)
    psum = jnp.sum(p)
    o_ref[...] += acc * scale_ref[0] + zero_ref[0] * psum


@jax.jit
def pv_inner(p, codes, scale, zero):
    """Fused context accumulation over the InnerQ value layout.

    p: (n,) with n = 32*C; codes: (C, d_h, G) int8; scale/zero: (C, d_h).
    Returns (d_h,) f32 context.
    """
    c, d_h, g = codes.shape
    assert g == GROUP and p.shape[0] == c * g
    return pl.pallas_call(
        _pv_inner_kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, GROUP), lambda i: (i, 0)),
            pl.BlockSpec((1, d_h, GROUP), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d_h), lambda i: (i, 0)),
            pl.BlockSpec((1, d_h), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((d_h,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d_h,), jnp.float32),
        interpret=True,
    )(p.reshape(c, GROUP), codes, scale, zero)


def effective_zero(scale, zero, mask, bits):
    """Fold the symmetric bias into a single effective zero term.

    Rust stores symmetric codes biased-unsigned; the reference and Pallas
    kernels carry *signed* symmetric codes, so symmetric groups have zero
    effective zero-term and asymmetric ones use Z (Eq. 14).
    """
    del bits
    return jnp.where(mask, zero, 0.0)


def vmem_report(n_tokens: int, d_h: int, bits: int, block_t: int = 256):
    """Static VMEM footprint estimate for one qk_inner block (DESIGN §Perf).

    Returns bytes resident per grid step; the target is to stay well under
    ~16 MiB of VMEM while keeping blocks MXU/VPU aligned.
    """
    ng = d_h // GROUP
    codes = block_t * d_h  # int8
    scales = block_t * ng * 4 * 2  # scale + zero, f32
    q = d_h * 4
    out = block_t * 4
    return {
        "codes_bytes": codes,
        "scale_bytes": scales,
        "q_bytes": q,
        "out_bytes": out,
        "total_bytes": codes + scales + q + out,
        "scale_traffic_ratio_vs_outer": 1.0 / 1.0,  # see kivi.vmem_report
    }
