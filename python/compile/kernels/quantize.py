"""Layer-1 Pallas quantization kernels (the Table-5 operations).

Each kernel quantizes a block of groups: computes the group statistics
(amax / min / max), derives the f16-rounded scale (and zero-point), and emits
int8 logical codes. The hybrid kernel evaluates both modes and selects per
group by reconstruction error (§4.1.2) entirely inside the block — no extra
HBM round-trip.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 32


def _f16(x):
    return x.astype(jnp.float16).astype(jnp.float32)


def _sym_block(vals, bits):
    qmax = (1 << (bits - 1)) - 1
    amax = jnp.max(jnp.abs(vals), axis=-1, keepdims=True)
    scale = _f16(jnp.where(amax > 0, amax / qmax, 1.0))
    codes = jnp.clip(jnp.round(vals / scale), -qmax, qmax)
    return codes, scale

def _asym_block(vals, bits):
    levels = (1 << bits) - 1
    lo = jnp.min(vals, axis=-1, keepdims=True)
    hi = jnp.max(vals, axis=-1, keepdims=True)
    zero = _f16(lo)
    scale = _f16(jnp.where(hi > lo, (hi - zero) / levels, 1.0))
    codes = jnp.clip(jnp.round((vals - zero) / scale), 0, levels)
    return codes, scale, zero


def _make_kernel(mode, bits):
    def kernel(x_ref, codes_ref, scale_ref, zero_ref, mask_ref):
        vals = x_ref[...]  # (T, ng, G)
        if mode == "sym":
            codes, scale = _sym_block(vals, bits)
            zero = jnp.zeros_like(scale)
            mask = jnp.zeros(scale.shape, jnp.int8)
        elif mode == "asym":
            codes, scale, zero = _asym_block(vals, bits)
            mask = jnp.ones(scale.shape, jnp.int8)
        else:  # hybrid
            cs, ss = _sym_block(vals, bits)
            ca, sa, za = _asym_block(vals, bits)
            es = jnp.sum((cs * ss - vals) ** 2, axis=-1, keepdims=True)
            ea = jnp.sum((ca * sa + za - vals) ** 2, axis=-1, keepdims=True)
            pick_a = ea < es
            codes = jnp.where(pick_a, ca, cs)
            scale = jnp.where(pick_a, sa, ss)
            zero = jnp.where(pick_a, za, 0.0)
            mask = pick_a.astype(jnp.int8)
        codes_ref[...] = codes.astype(jnp.int8)
        scale_ref[...] = scale[..., 0]
        zero_ref[...] = zero[..., 0]
        mask_ref[...] = mask[..., 0]

    return kernel


@functools.partial(jax.jit, static_argnames=("bits", "mode", "block_t"))
def quantize_groups(x, bits: int, mode: str = "sym", block_t: int = 64):
    """Quantize grouped values with a Pallas kernel.

    x: (n, ng, G) f32 — any grouped layout (the caller reshapes).
    Returns (codes int8, scale f32 (n, ng), zero f32, mask int8).
    """
    n, ng, g = x.shape
    assert g == GROUP
    block_t = min(block_t, n)
    assert n % block_t == 0
    kernel = _make_kernel(mode, bits)
    return pl.pallas_call(
        kernel,
        grid=(n // block_t,),
        in_specs=[pl.BlockSpec((block_t, ng, GROUP), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((block_t, ng, GROUP), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_t, ng), lambda i: (i, 0)),
            pl.BlockSpec((block_t, ng), lambda i: (i, 0)),
            pl.BlockSpec((block_t, ng), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, ng, GROUP), jnp.int8),
            jax.ShapeDtypeStruct((n, ng), jnp.float32),
            jax.ShapeDtypeStruct((n, ng), jnp.float32),
            jax.ShapeDtypeStruct((n, ng), jnp.int8),
        ],
        interpret=True,
    )(x)
