"""Pure-jnp reference oracle for the InnerQ quantization math.

This module is the single source of truth the Pallas kernels (L1) and the
Rust kernels (L3, via golden vectors) are validated against. It mirrors the
paper's equations directly:

* Eq. (10)-(12): group-wise asymmetric quantization;
* Eq. (13) as clarified in DESIGN.md: signed symmetric quantization with
  codes in [-(2^{b-1}-1), 2^{b-1}-1];
* Eq. (14) / §4.1.2: hybrid per-group mode selection by reconstruction error;
* §4.4: fused dequantize-GEMV with inner- and outer-dimension grouping.

Scales and zero-points are rounded through float16 exactly as the stored
representation (Table 3 budgets FP16 overheads), matching the Rust side's
software-f16 path bit-for-bit.
"""

import jax.numpy as jnp

GROUP = 32


def f16_round(x):
    """Round f32 values through IEEE float16 storage precision."""
    return x.astype(jnp.float16).astype(jnp.float32)


def sym_qmax(bits: int) -> int:
    return (1 << (bits - 1)) - 1


def quantize_sym(groups, bits):
    """Symmetric group quantization.

    groups: (..., G) f32. Returns (codes int32 in [-qmax, qmax], scale f32).
    """
    qmax = sym_qmax(bits)
    amax = jnp.max(jnp.abs(groups), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    scale = f16_round(scale)
    codes = jnp.clip(jnp.round(groups / scale), -qmax, qmax).astype(jnp.int32)
    return codes, scale


def dequantize_sym(codes, scale):
    return codes.astype(jnp.float32) * scale


def quantize_asym(groups, bits):
    """Asymmetric group quantization (Eq. 10-12).

    Returns (codes int32 in [0, 2^b-1], scale f32, zero f32).
    """
    levels = (1 << bits) - 1
    lo = jnp.min(groups, axis=-1, keepdims=True)
    hi = jnp.max(groups, axis=-1, keepdims=True)
    zero = f16_round(lo)
    scale = jnp.where(hi > lo, (hi - zero) / levels, 1.0)
    scale = f16_round(scale)
    codes = jnp.clip(jnp.round((groups - zero) / scale), 0, levels).astype(jnp.int32)
    return codes, scale, zero


def dequantize_asym(codes, scale, zero):
    return codes.astype(jnp.float32) * scale + zero


def quantize_hybrid(groups, bits):
    """Hybrid quantization (§4.1.2): per-group sym/asym by squared error.

    Returns (codes int32, scale f32, zero f32, mask bool) where mask=True
    means the group is asymmetric (the paper's M). Symmetric codes are
    reported in signed form; `dequantize_hybrid` applies Eq. (14).
    """
    cs, ss = quantize_sym(groups, bits)
    ca, sa, za = quantize_asym(groups, bits)
    err_s = jnp.sum((dequantize_sym(cs, ss) - groups) ** 2, axis=-1, keepdims=True)
    err_a = jnp.sum((dequantize_asym(ca, sa, za) - groups) ** 2, axis=-1, keepdims=True)
    mask = err_a < err_s  # ties favour symmetric
    codes = jnp.where(mask, ca, cs)
    scale = jnp.where(mask, sa, ss)
    zero = jnp.where(mask, za, 0.0)
    return codes, scale, zero, mask


def dequantize_hybrid(codes, scale, zero, mask):
    """Eq. (14): dequant = S*K + M*Z (M folded into zero here)."""
    del mask  # zero is already masked (0 for symmetric groups)
    return codes.astype(jnp.float32) * scale + zero


# ---------------------------------------------------------------------------
# Grouped cache quantization (inner / outer layouts) and fused GEMVs.
# ---------------------------------------------------------------------------


def quantize_key_inner(k, bits, mode="sym"):
    """InnerQ key layout: per-token groups along d_h.

    k: (n, d_h). Returns dict with codes (n, d_h/G, G) and params
    (n, d_h/G, 1) arrays.
    """
    n, d_h = k.shape
    groups = k.reshape(n, d_h // GROUP, GROUP)
    return _quantize_groups(groups, bits, mode)


def quantize_val_inner(v, bits, mode="sym"):
    """InnerQ value layout: per-channel groups along 32-token chunks.

    v: (n, d_h) with n % 32 == 0. Returns groups shaped
    (n/G, d_h, G): chunk-major, channel rows, token columns.
    """
    n, d_h = v.shape
    assert n % GROUP == 0
    chunks = v.reshape(n // GROUP, GROUP, d_h).transpose(0, 2, 1)  # (C, d_h, G)
    return _quantize_groups(chunks, bits, mode)


def quantize_key_outer(k, bits, mode="asym"):
    """KIVI key layout: per-channel groups along 32-token chunks.

    k: (n, d_h), n % 32 == 0. Groups shaped (n/G, d_h, G) like val_inner —
    the layouts are transposes of each other; what differs is which GEMV
    axis the groups align with.
    """
    return quantize_val_inner(k, bits, mode)


def quantize_val_outer(v, bits, mode="asym"):
    """KIVI value layout: per-token groups along channels."""
    return quantize_key_inner(v, bits, mode)


def _quantize_groups(groups, bits, mode):
    if mode == "sym":
        codes, scale = quantize_sym(groups, bits)
        return {"codes": codes, "scale": scale, "zero": jnp.zeros_like(scale),
                "mask": jnp.zeros(scale.shape, bool), "mode": mode, "bits": bits}
    if mode == "asym":
        codes, scale, zero = quantize_asym(groups, bits)
        return {"codes": codes, "scale": scale, "zero": zero,
                "mask": jnp.ones(scale.shape, bool), "mode": mode, "bits": bits}
    if mode == "hybrid":
        codes, scale, zero, mask = quantize_hybrid(groups, bits)
        return {"codes": codes, "scale": scale, "zero": zero, "mask": mask,
                "mode": mode, "bits": bits}
    raise ValueError(f"unknown mode {mode}")


def dequantize_groups(q):
    return q["codes"].astype(jnp.float32) * q["scale"] + q["zero"]


def qk_inner(q, kq):
    """Fused dequant-GEMV scores, InnerQ key layout (reference).

    q: (d_h,); kq: quantize_key_inner output. Returns (n,) scores.
    Formulated the way the fused kernel computes it: group-partial code dot
    products scaled once per group, plus the zero term times the group's
    query prefix sum.
    """
    codes, scale, zero = kq["codes"], kq["scale"], kq["zero"]
    n, n_groups, g = codes.shape
    qg = q.reshape(n_groups, g)
    acc = jnp.einsum("ngi,gi->ng", codes.astype(jnp.float32), qg)
    qsum = jnp.sum(qg, axis=-1)
    return jnp.sum(acc * scale[..., 0] + zero[..., 0] * qsum[None, :], axis=-1)


def pv_inner(p, vq):
    """Fused context accumulation, InnerQ value layout (reference).

    p: (n,); vq: quantize_val_inner output with chunks (C, d_h, G).
    Returns (d_h,).
    """
    codes, scale, zero = vq["codes"], vq["scale"], vq["zero"]
    n_chunks, d_h, g = codes.shape
    pc = p.reshape(n_chunks, g)
    acc = jnp.einsum("cdg,cg->cd", codes.astype(jnp.float32), pc)
    psum = jnp.sum(pc, axis=-1)
    out = acc * scale[..., 0] + zero[..., 0] * psum[:, None]
    return jnp.sum(out, axis=0)


def qk_outer(q, kq):
    """Fused scores, KIVI key layout: per-channel scales hoisted into q."""
    codes, scale, zero = kq["codes"], kq["scale"], kq["zero"]
    n_chunks, d_h, g = codes.shape
    qs = q[None, :] * scale[..., 0]           # (C, d_h) hoisted q*s
    zacc = jnp.sum(q[None, :] * zero[..., 0], axis=-1)  # (C,)
    scores = jnp.einsum("cdg,cd->cg", codes.astype(jnp.float32), qs)
    return (scores + zacc[:, None]).reshape(-1)


def pv_outer(p, vq):
    """Fused context, KIVI value layout: per-token groups along channels."""
    codes, scale, zero = vq["codes"], vq["scale"], vq["zero"]
    n, n_groups, g = codes.shape
    deq = codes.astype(jnp.float32) * scale + zero  # (n, d_h/G, G)
    return jnp.einsum("n,ngi->gi", p, deq).reshape(-1)


def attention_reference(q, k, v):
    """Plain FP decode attention: one query against n cached tokens."""
    d_h = q.shape[-1]
    s = k @ q / jnp.sqrt(d_h)
    p = jnp.exp(s - jnp.max(s))
    p = p / jnp.sum(p)
    return p @ v
