"""L1 correctness: Pallas kernels vs the pure-jnp reference oracle.

The hypothesis sweeps exercise shapes (token counts, head dims, block sizes),
bit-widths, and modes; assert_allclose against ref.py is the core L1 signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import innerq, kivi, quantize, ref

jax.config.update("jax_platform_name", "cpu")

GROUP = 32


def rand(key, shape, outliers=0.0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    x = jax.random.normal(k1, shape, jnp.float32)
    if outliers:
        mask = jax.random.uniform(k2, shape) < outliers
        x = jnp.where(mask, x * 8.0, x)
    return x


# ---------------------------------------------------------------------------
# quantize kernels vs reference
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([32, 64, 256]),
    ng=st.sampled_from([1, 2, 4]),
    bits=st.sampled_from([2, 3, 4]),
    mode=st.sampled_from(["sym", "asym", "hybrid"]),
    seed=st.integers(0, 2**16),
)
def test_quantize_kernel_matches_ref(n, ng, bits, mode, seed):
    x = rand(seed, (n, ng, GROUP), outliers=0.05)
    codes, scale, zero, mask = quantize.quantize_groups(x, bits, mode, block_t=32)
    want = ref._quantize_groups(x, bits, mode)
    # Codes may differ by 1 at exact rounding-tie boundaries (XLA fuses the
    # (v-z)/s expression differently inside the Pallas block, a 1-ulp
    # difference that flips round-to-nearest at ties). Require <=1 code step
    # and identical dequantized error bound.
    dc = np.abs(np.asarray(codes, np.int32) - np.asarray(want["codes"], np.int32))
    assert dc.max() <= 1, f"code diff {dc.max()}"
    assert (dc != 0).mean() < 0.01, f"too many tie flips: {(dc != 0).mean()}"
    np.testing.assert_allclose(np.asarray(scale), np.asarray(want["scale"][..., 0]), rtol=0)
    np.testing.assert_allclose(np.asarray(zero), np.asarray(want["zero"][..., 0]), rtol=0)
    np.testing.assert_array_equal(np.asarray(mask, bool), np.asarray(want["mask"][..., 0]))


def test_quantize_round_trip_error_bound():
    x = rand(7, (64, 4, GROUP))
    for bits in (2, 3, 4):
        codes, scale, zero, _ = quantize.quantize_groups(x, bits, "hybrid")
        deq = np.asarray(codes, np.float32) * np.asarray(scale)[..., None] + np.asarray(zero)[..., None]
        step = np.asarray(scale)[..., None]
        err = np.abs(deq - np.asarray(x))
        assert np.all(err <= 0.5 * step + 1e-3), f"bits={bits}"


# ---------------------------------------------------------------------------
# fused dequant-GEMV kernels vs reference
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([256, 512, 1024]),
    d_h=st.sampled_from([32, 64, 128]),
    bits=st.sampled_from([2, 3, 4]),
    mode=st.sampled_from(["sym", "asym", "hybrid"]),
    block_t=st.sampled_from([64, 256]),
    seed=st.integers(0, 2**16),
)
def test_qk_inner_pallas_matches_ref(n, d_h, bits, mode, block_t, seed):
    k = rand(seed, (n, d_h), outliers=0.02)
    q = rand(seed + 1, (d_h,))
    kq = ref.quantize_key_inner(k, bits, mode)
    want = ref.qk_inner(q, kq)
    zeff = innerq.effective_zero(kq["scale"], kq["zero"], kq["mask"], bits)
    got = innerq.qk_inner(
        q, kq["codes"].astype(jnp.int8), kq["scale"][..., 0], zeff[..., 0], block_t=block_t
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([32, 256, 512]),
    d_h=st.sampled_from([32, 64, 128]),
    bits=st.sampled_from([2, 3]),
    mode=st.sampled_from(["sym", "hybrid"]),
    seed=st.integers(0, 2**16),
)
def test_pv_inner_pallas_matches_ref(n, d_h, bits, mode, seed):
    v = rand(seed, (n, d_h))
    p = jax.nn.softmax(rand(seed + 1, (n,)))
    vq = ref.quantize_val_inner(v, bits, mode)
    want = ref.pv_inner(p, vq)
    zeff = innerq.effective_zero(vq["scale"], vq["zero"], vq["mask"], bits)
    got = innerq.pv_inner(p, vq["codes"].astype(jnp.int8), vq["scale"][..., 0], zeff[..., 0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([32, 256]),
    d_h=st.sampled_from([64, 128]),
    bits=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**16),
)
def test_qk_outer_pallas_matches_ref(n, d_h, bits, seed):
    k = rand(seed, (n, d_h), outliers=0.02)
    q = rand(seed + 1, (d_h,))
    kq = ref.quantize_key_outer(k, bits, "asym")
    want = ref.qk_outer(q, kq)
    got = kivi.qk_outer(q, kq["codes"].astype(jnp.int8), kq["scale"][..., 0], kq["zero"][..., 0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([64, 256, 512]),
    d_h=st.sampled_from([32, 128]),
    bits=st.sampled_from([2, 3]),
    seed=st.integers(0, 2**16),
)
def test_pv_outer_pallas_matches_ref(n, d_h, bits, seed):
    v = rand(seed, (n, d_h))
    p = jax.nn.softmax(rand(seed + 1, (n,)))
    vq = ref.quantize_val_outer(v, bits, "asym")
    want = ref.pv_outer(p, vq)
    got = kivi.pv_outer(
        p, vq["codes"].astype(jnp.int8), vq["scale"][..., 0], vq["zero"][..., 0], block_t=64
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# reference self-consistency: fused forms == dequantize-then-matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["sym", "asym", "hybrid"])
def test_ref_qk_inner_equals_dequant_matmul(mode):
    k = rand(3, (128, 64), outliers=0.05)
    q = rand(4, (64,))
    kq = ref.quantize_key_inner(k, 3, mode)
    deq = ref.dequantize_groups(kq).reshape(128, 64)
    want = deq @ q
    got = ref.qk_inner(q, kq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode", ["sym", "hybrid"])
def test_ref_pv_inner_equals_dequant_matmul(mode):
    v = rand(5, (96, 64))
    p = jax.nn.softmax(rand(6, (96,)))
    vq = ref.quantize_val_inner(v, 2, mode)
    # chunks (C, d_h, G) -> (C, G, d_h) -> (n, d_h)
    deq = ref.dequantize_groups(vq).transpose(0, 2, 1).reshape(96, 64)
    want = p @ deq
    got = ref.pv_inner(p, vq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_ref_outer_layouts_equal_dequant_matmul():
    k = rand(7, (64, 128), outliers=0.05)
    q = rand(8, (128,))
    kq = ref.quantize_key_outer(k, 2, "asym")
    deq = ref.dequantize_groups(kq).transpose(0, 2, 1).reshape(64, 128)
    np.testing.assert_allclose(
        np.asarray(ref.qk_outer(q, kq)), np.asarray(deq @ q), rtol=1e-4, atol=1e-4
    )
    v = rand(9, (64, 128))
    p = jax.nn.softmax(rand(10, (64,)))
    vq = ref.quantize_val_outer(v, 2, "asym")
    deqv = ref.dequantize_groups(vq).reshape(64, 128)
    np.testing.assert_allclose(
        np.asarray(ref.pv_outer(p, vq)), np.asarray(p @ deqv), rtol=1e-4, atol=1e-4
    )


def test_hybrid_mask_mostly_symmetric_on_gaussianish_data():
    # §6.2: hybrid overwhelmingly favours symmetric on real cache data; on
    # zero-mean data the symmetric grid usually wins after the exact-zero
    # advantage. Just check the mask is produced and is mostly sym for
    # zero-centered spiky data.
    x = rand(11, (256, 4, GROUP))
    spikes = jnp.zeros_like(x).at[:, :, 0].set(3.0).at[:, :, 1].set(-3.0)
    x = jnp.where(jnp.abs(x) < 0.1, x, 0.0) + spikes
    kq = ref._quantize_groups(x, 3, "hybrid")
    frac_asym = float(jnp.mean(kq["mask"]))
    assert frac_asym < 0.2, f"asym fraction {frac_asym}"
