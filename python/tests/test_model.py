"""L2 correctness: corpus grammar, model shapes, staged-vs-full parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus, model, train

jax.config.update("jax_platform_name", "cpu")

CFG = model.ModelConfig(n_layers=2)  # smaller for test speed


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# corpus
# ---------------------------------------------------------------------------

def test_corpus_round_trip():
    rng = np.random.default_rng(0)
    text = corpus.sample_sequence(rng, 8, 3)
    assert corpus.decode(corpus.encode(text)) == text


def test_corpus_queries_are_recallable():
    rng = np.random.default_rng(1)
    text = corpus.sample_sequence(rng, 10, 5)
    # every query's value must match its latest assignment
    body, queries = text.split("?", 1)
    assigns = {}
    for part in body.split(";"):
        if "=" in part:
            n, v = part.split("=")
            assigns[n] = v
    for qpart in ("?" + queries).rstrip(".").split(";"):
        n, v = qpart[1:].split("=")
        assert assigns[n] == v, f"query {n}"


def test_query_positions_target_value_digits():
    rng = np.random.default_rng(2)
    toks = corpus.sample_tokens(rng, 6, 4)
    pos = corpus.query_positions(toks)
    assert len(pos) == 8  # 2 digits per query
    for p, target in pos:
        assert toks[p + 1] == target


def test_vocab_covers_charset():
    assert corpus.vocab_size() == len(corpus.CHARSET) + 1
    rng = np.random.default_rng(3)
    toks = corpus.sample_tokens(rng, 20, 10)
    assert toks.max() < corpus.vocab_size()
    assert toks.min() >= 0


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def test_forward_shapes(params):
    tokens = jnp.zeros((2, 16), jnp.int32)
    logits = model.forward(CFG, params, tokens)
    assert logits.shape == (2, 16, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality(params):
    """Changing a future token must not change past logits."""
    rng = np.random.default_rng(4)
    t1 = jnp.asarray(corpus.sample_tokens(rng, 6, 2, length=32))[None]
    t2 = t1.at[0, 20].set((int(t1[0, 20]) % (CFG.vocab - 1)) + 1)
    l1 = model.forward(CFG, params, t1)
    l2 = model.forward(CFG, params, t2)
    np.testing.assert_allclose(l1[0, :20], l2[0, :20], atol=1e-5)
    assert not np.allclose(l1[0, 20:], l2[0, 20:], atol=1e-5)


def test_rope_is_relative(params):
    """RoPE scores depend on relative position: shifting both q and k
    positions by a constant leaves q.k unchanged."""
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 2, CFG.d_h))
    p0 = jnp.array([3])
    p1 = jnp.array([10])
    shift = 7
    a = model.rope(x, p0, CFG.rope_theta)[0]
    b = model.rope(x, p1, CFG.rope_theta)[0]
    a2 = model.rope(x, p0 + shift, CFG.rope_theta)[0]
    b2 = model.rope(x, p1 + shift, CFG.rope_theta)[0]
    dot1 = jnp.sum(a[0] * b[1])
    dot2 = jnp.sum(a2[0] * b2[1])
    assert abs(float(dot1 - dot2)) < 1e-4


def test_staged_decode_matches_full_forward(params):
    """The staged decode pipeline (what Rust drives) must reproduce the full
    causal forward logits exactly (FP cache)."""
    rng = np.random.default_rng(5)
    tokens = corpus.sample_tokens(rng, 4, 2)[:24]
    full = model.forward(CFG, params, jnp.asarray(tokens)[None])[0]
    staged = model.decode_reference(CFG, params, jnp.asarray(tokens))
    np.testing.assert_allclose(np.asarray(staged), np.asarray(full), atol=2e-4)


def test_prefill_matches_forward(params):
    rng = np.random.default_rng(6)
    tokens = jnp.asarray(corpus.sample_tokens(rng, 4, 2, length=32))[None]
    logits, ks, vs = model.prefill_fn(CFG, params, tokens)
    full = model.forward(CFG, params, tokens)[0]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full), atol=1e-4)
    assert ks.shape == (CFG.n_layers, 32, CFG.n_kv_heads, CFG.d_h)
    # K/V match the qkv stage at each position
    h = params["embed"][tokens]
    q0, k0, v0 = model.qkv_fn(CFG, params, 0, h[:, 0], jnp.array([0], jnp.int32))
    np.testing.assert_allclose(np.asarray(ks[0, 0]), np.asarray(k0[0]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(vs[0, 0]), np.asarray(v0[0]), atol=1e-4)


def test_padded_prefill_prefix_is_stable(params):
    """Padding the prompt must not change logits/K/V at real positions."""
    rng = np.random.default_rng(7)
    toks = corpus.sample_tokens(rng, 4, 2)[:20]
    a = model.prefill_fn(CFG, params, jnp.asarray(toks)[None])
    padded = np.concatenate([toks, np.zeros(12, np.int32)])
    b = model.prefill_fn(CFG, params, jnp.asarray(padded)[None])
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0][:20]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1][:, :20]), atol=1e-4)


def test_training_reduces_loss():
    cfg = model.ModelConfig(n_layers=1, d_model=64, d_ff=128, n_q_heads=2, n_kv_heads=1)
    params, history = train.train(cfg, steps=30, batch_size=4, seq_len=96, log_every=29)
    assert history[-1][1] < history[0][1], f"loss did not drop: {history}"


def test_quantized_decode_reference_runs(params):
    """The simulated-quantized decode path degrades gracefully, not wildly."""
    rng = np.random.default_rng(8)
    tokens = corpus.sample_tokens(rng, 12, 4)[:80]
    fp = model.decode_reference(CFG, params, jnp.asarray(tokens))
    q = model.decode_reference(
        CFG, params, jnp.asarray(tokens), quant={"key_bits": 3, "val_bits": 3, "mode": "sym"}
    )
    # same shape, finite, and not identical (quantization kicked in at t>=64)
    assert q.shape == fp.shape
    assert bool(jnp.all(jnp.isfinite(q)))
    assert not np.allclose(np.asarray(q[-1]), np.asarray(fp[-1]), atol=1e-6)
    # top-1 agreement at the last steps should still be high-ish
    agree = np.mean(
        np.argmax(np.asarray(q[64:]), -1) == np.argmax(np.asarray(fp[64:]), -1)
    )
    assert agree >= 0.5, f"agreement {agree}"
