//! Window ablation driver (Fig. 5 companion): sweep the sink/recent split of
//! the 128-token high-precision window for one method and print the quality
//! curve. A focused version of `innerq exp fig5`.
//!
//! ```bash
//! cargo run --release --example ablation_windows [method]
//! ```

use anyhow::Result;
use innerq::eval::{evaluate, EvalConfig};
use innerq::runtime::Manifest;
use innerq::QuantMethod;

fn main() -> Result<()> {
    let method = std::env::args()
        .nth(1)
        .and_then(|s| QuantMethod::parse(&s))
        .unwrap_or(QuantMethod::InnerQSmall);
    let manifest = Manifest::load("artifacts")?;
    let cfg = EvalConfig { n_docs: 4, n_assign: 40, n_queries: 10, seed: 55 };

    eprintln!("[ablation] baseline ...");
    let (base, base_logits) = evaluate(&manifest, QuantMethod::BaselineFp16.config(), cfg, None)?;
    println!(
        "baseline_fp16: NLL {:.4}, acc {:.1}%",
        base.nll,
        base.accuracy * 100.0
    );

    println!("\nw_sink  w_recent  NLL      acc%   agree%  (method: {})", method.name());
    for w_sink in [0usize, 16, 32, 64, 96, 128] {
        let mut mc = method.config();
        mc.w_sink = w_sink;
        mc.w_recent = 128 - w_sink;
        let (r, _) = evaluate(&manifest, mc, cfg, Some(&base_logits))?;
        println!(
            "{:>6} {:>9} {:>8.4} {:>6.1} {:>8.1}",
            w_sink,
            mc.w_recent,
            r.nll,
            r.accuracy * 100.0,
            r.agreement * 100.0
        );
    }
    Ok(())
}
