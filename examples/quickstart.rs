//! Quickstart: load the AOT artifacts, start an InnerQ-quantized engine,
//! and generate a completion for one recall prompt.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use innerq::coordinator::{Engine, Request, Scheduler};
use innerq::runtime::Manifest;
use innerq::QuantMethod;

fn main() -> Result<()> {
    let manifest = Manifest::load("artifacts")?;
    println!(
        "model: {} layers, d_model {}, vocab {} (train loss {:.3})",
        manifest.model.n_layers, manifest.model.d_model, manifest.model.vocab,
        manifest.final_train_loss
    );

    // The paper's flagship variant: 3-bit inner-grouped K & V, sink+recent
    // high-precision windows, per-channel key normalization.
    let method = QuantMethod::InnerQBase;
    println!("compiling {} stages for {} ...", manifest.artifacts.len(), method.name());
    let engine = Engine::new(manifest, method.config())?;
    let mut sched = Scheduler::new(engine, 1 << 30);

    let prompt = "a=41;b=07;c=93;d=22;e=58;f=64;g=11;h=85;i=30;j=76;a=55;c=12;?b=";
    sched.submit(Request::new(1, prompt, 12));
    let done = sched.run_to_completion()?;
    let c = &done[0];
    println!("\nprompt:     {prompt}");
    println!("completion: {}", c.text);
    println!(
        "ttft: {} µs, total: {} µs, {} tokens generated",
        c.ttft_us, c.total_us, c.n_generated
    );
    println!("\n(b was assigned 07 — a faithful cache recalls it.)");
    Ok(())
}
