//! Long-context generation: prefill a multi-thousand-token document, then
//! compare decode-path behaviour and cache memory across methods — the
//! paper's motivating workload (§1: "long-context generation", Table 2).
//!
//! ```bash
//! cargo run --release --example longcontext [n_assign]
//! ```

use anyhow::Result;
use innerq::coordinator::Engine;
use innerq::quant::bitwidth;
use innerq::runtime::Manifest;
use innerq::workload::corpus::CorpusGen;
use innerq::QuantMethod;
use std::time::Instant;

fn main() -> Result<()> {
    let n_assign: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(380);
    let manifest = Manifest::load("artifacts")?;
    let mut gen = CorpusGen::new(4242);
    let doc = gen.document(n_assign, 6);
    let prompt = &doc.text[..doc.query_start + 3]; // through the first "?x="
    let tokens = {
        let mut t = vec![manifest.bos];
        t.extend(manifest.encode(prompt)?);
        t
    };
    println!(
        "document: {} chars ({} tokens prefilled), querying '{}'",
        doc.text.len(),
        tokens.len(),
        &doc.queries[0].0
    );

    println!(
        "\n{:<16} {:>9} {:>12} {:>12} {:>10} {:>8}",
        "method", "bits/num", "prefill µs", "decode µs/t", "cache KiB", "answer"
    );
    for method in [
        QuantMethod::BaselineFp16,
        QuantMethod::Kivi,
        QuantMethod::TurboQuant,
        QuantMethod::InnerQBase,
        QuantMethod::InnerQHybrid,
        QuantMethod::InnerQSmall,
    ] {
        let engine = Engine::new(manifest.clone(), method.config())?;
        let t0 = Instant::now();
        let mut seq = engine.prefill(&tokens)?;
        let prefill_us = t0.elapsed().as_micros();

        // greedy-decode the queried value
        let mut answer = String::new();
        let mut next = Engine::argmax(&seq.last_logits);
        let t1 = Instant::now();
        let steps = 4;
        for _ in 0..steps {
            engine.decode_step(&mut [&mut seq], &[next])?;
            answer.push_str(&engine.manifest.decode_text(&[next]));
            next = Engine::argmax(&seq.last_logits);
        }
        let decode_us = t1.elapsed().as_micros() / steps as u128;

        let bits = bitwidth::bit_width(&method.config(), engine.manifest.model.d_h).effective();
        println!(
            "{:<16} {:>9.2} {:>12} {:>12} {:>10.1} {:>8}",
            method.name(),
            bits,
            prefill_us,
            decode_us,
            seq.cache_bytes() as f64 / 1024.0,
            answer
        );
    }
    println!("\nexpected answer: {} (latest assignment of '{}')", doc.queries[0].1, doc.queries[0].0);
    Ok(())
}
