//! End-to-end serving driver (the paper's deployment scenario): start the
//! TCP server with a quantized engine, fire a batch of concurrent client
//! requests from the workload trace, and report latency/throughput plus
//! recall correctness. This is the EXPERIMENTS.md §E2E run.
//!
//! ```bash
//! cargo run --release --example serve_requests [method] [n_requests]
//! ```

use anyhow::Result;
use innerq::server::{serve, Client};
use innerq::workload::trace::{generate, TraceConfig};
use innerq::QuantMethod;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let method = args
        .get(1)
        .and_then(|s| QuantMethod::parse(s))
        .unwrap_or(QuantMethod::InnerQBase);
    let n_requests: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(12);

    eprintln!("[e2e] compiling stages (method={}) ...", method.name());
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop_srv = stop.clone();
    let server = std::thread::spawn(move || -> Result<()> {
        // Engine lives on the server thread (PJRT client is thread-local).
        let manifest = innerq::runtime::Manifest::load("artifacts")?;
        let engine = innerq::coordinator::Engine::new(manifest, method.config())?;
        let sched = innerq::coordinator::Scheduler::new(engine, 1 << 30);
        serve(sched, "127.0.0.1:0", stop_srv, move |a| {
            let _ = addr_tx.send(a);
        })
    });
    let addr = addr_rx.recv()?;
    eprintln!("[e2e] server on {addr}");

    let reqs = generate(TraceConfig {
        n_requests,
        n_vars: 40,
        n_queries: 2,
        max_new_tokens: 8,
        seed: 11,
    });

    // Concurrent clients, one per request. Every fourth request is tagged
    // interactive with a generous deadline, exercising the SLO fields in
    // the wire protocol end-to-end.
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (i, r) in reqs.into_iter().enumerate() {
        handles.push(std::thread::spawn(move || -> Result<(String, String, u64, u64)> {
            let mut c = Client::connect(addr)?;
            let resp = if i % 4 == 0 {
                c.generate_with(
                    &r.prompt,
                    r.max_new_tokens,
                    innerq::coordinator::Priority::Interactive,
                    Some(60_000.0),
                )?
            } else {
                c.generate(&r.prompt, r.max_new_tokens)?
            };
            Ok((
                r.prompt.clone(),
                resp.get("text").as_str().unwrap_or("").to_string(),
                resp.get("ttft_us").as_f64().unwrap_or(0.0) as u64,
                resp.get("total_us").as_f64().unwrap_or(0.0) as u64,
            ))
        }));
    }

    let mut correct = 0usize;
    let mut total = 0usize;
    let mut ttfts = Vec::new();
    let mut totals = Vec::new();
    let mut gen_tokens = 0usize;
    for h in handles {
        let (prompt, text, ttft, total_us) = h.join().unwrap()?;
        // ground truth: prompt ends "?x=" — find x's latest assignment
        // (search only the assignment body; the query stem also matches)
        let name = prompt.chars().rev().nth(1).unwrap();
        let body = &prompt[..prompt.rfind('?').unwrap_or(prompt.len())];
        let want = body
            .match_indices(&format!("{name}="))
            .map(|(p, _)| &body[p + 2..p + 4])
            .last()
            .unwrap_or("??");
        let got = text.get(0..2).unwrap_or("");
        correct += (got == want) as usize;
        total += 1;
        gen_tokens += text.len();
        ttfts.push(ttft);
        totals.push(total_us);
        println!("  ?{name}= -> {got:<4} (want {want})  ttft {ttft:>7}µs total {total_us:>8}µs");
    }
    let wall = t0.elapsed().as_secs_f64();
    ttfts.sort_unstable();
    totals.sort_unstable();
    println!("\n== E2E serving report ({}) ==", method.name());
    println!(
        "requests: {total}, recall accuracy: {:.0}%",
        100.0 * correct as f64 / total as f64
    );
    println!(
        "ttft p50/p95: {} / {} µs, total p50/p95: {} / {} µs",
        ttfts[ttfts.len() / 2],
        ttfts[(ttfts.len() * 95 / 100).min(ttfts.len() - 1)],
        totals[totals.len() / 2],
        totals[(totals.len() * 95 / 100).min(totals.len() - 1)]
    );
    println!(
        "wall: {wall:.2}s, throughput: {:.1} req/s, {:.0} gen tok/s",
        total as f64 / wall,
        gen_tokens as f64 / wall
    );

    stop.store(true, Ordering::Relaxed);
    let _ = std::net::TcpStream::connect(addr); // poke the acceptor awake
    let _ = server.join();
    Ok(())
}
